"""Beyond-paper ablations of the SA model (the paper's optional dimensions):

  * array-size sweep — how the skew's saving scales with R (the saving is
    ~R cycles/tile, so bigger arrays gain more on latency-bound layers);
  * input-format sweep — the paper evaluates Bfloat16; FP8 halves the
    multiplier but the exponent path (the skew's target) stays, so the
    cycle-level saving is format-independent while area/power scale down;
  * batch amortization — streaming more rows (M) amortizes the fill: the
    skew's advantage decays as 1/M (the Fig. 7/8 'early layer' effect).
"""
from __future__ import annotations

from repro.core import energy as E
from repro.core.systolic import BASELINE, SKEWED, SAConfig, gemm_latency


def rows():
    out = []
    # 1. array-size sweep (MobileNet totals)
    for n in (64, 128, 256):
        t = E.network_totals("mobilenet", rows=n, cols=n)
        out.append({"table": "ablate_array", "array": f"{n}x{n}",
                    "latency_saving_pct": round(100 * t["latency_saving"], 1),
                    "energy_saving_pct": round(100 * t["energy_saving"], 1)})
    # 2. format sweep: cycle savings are format-independent (the pipeline
    # reorganization is in the exponent path); report per-GEMM cycles
    for fmt, rel_area in (("bf16", 1.00), ("fp8_e4m3", 0.52), ("fp8_e5m2", 0.52)):
        cb = gemm_latency(49, 1024, 1024, SAConfig(pipeline=BASELINE))
        cs = gemm_latency(49, 1024, 1024, SAConfig(pipeline=SKEWED))
        out.append({"table": "ablate_format", "format": fmt,
                    "cycles_base": cb, "cycles_skew": cs,
                    "saving_pct": round(100 * (1 - cs / cb), 1),
                    "rel_pe_area_est": rel_area})
    # 3. batch amortization: skew saving vs streamed rows M
    for m in (1, 16, 128, 1024, 16384):
        cb = gemm_latency(m, 1024, 1024, SAConfig(pipeline=BASELINE))
        cs = gemm_latency(m, 1024, 1024, SAConfig(pipeline=SKEWED))
        out.append({"table": "ablate_batch", "M": m,
                    "saving_pct": round(100 * (1 - cs / cb), 2)})
    return out


def main():
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
