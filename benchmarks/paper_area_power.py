"""Paper §IV synthesis table: area/power of the two designs (128×128 SA)."""
from repro.core import energy as E
from repro.core.systolic import BASELINE, SKEWED, SAConfig


def rows():
    out = []
    for pipe in (BASELINE, SKEWED):
        sa = SAConfig(pipeline=pipe)
        out.append({
            "table": "area_power", "design": pipe,
            "rel_area": E.REL_AREA[pipe], "rel_power": E.REL_POWER[pipe],
            "area_mm2": round(E.array_area_mm2(sa), 2),
            "power_w": round(E.array_power_w(sa), 2),
        })
    out.append({"table": "area_power", "design": "overhead",
                "rel_area": f"+{(E.REL_AREA[SKEWED]-1)*100:.0f}% (paper +9%)",
                "rel_power": f"+{(E.REL_POWER[SKEWED]-1)*100:.0f}% (paper +7%)"})
    return out


def main():
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
