"""Kernel micro-bench: sa_matmul (interpret) vs the jnp reference, the
bit-exact fp_emu datapath kernel, and the fp8 quantize kernel.

Wall times on this CPU container are interpret-mode numbers (the kernels
target TPU); the point of the table is correctness overhead accounting and
block-shape behaviour, not absolute speed.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fpformats import BF16, quantize_np
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps * 1e6


def rows():
    rng = np.random.default_rng(0)
    out = []
    for m, k, n in ((256, 256, 256), (512, 1024, 512)):
        a = jnp.asarray(quantize_np(rng.standard_normal((m, k)), BF16),
                        jnp.bfloat16)
        w = jnp.asarray(quantize_np(rng.standard_normal((k, n)), BF16),
                        jnp.bfloat16)
        us_ref = _time(lambda a, w: ref.sa_matmul_ref(a, w), a, w)
        for bm, bn, bk in ((128, 128, 256), (256, 256, 512)):
            us = _time(lambda a, w: ops.sa_matmul(a, w, bm=bm, bn=bn, bk=bk),
                       a, w)
            err = float(jnp.max(jnp.abs(
                ops.sa_matmul(a, w, bm=bm, bn=bn, bk=bk)
                - ref.sa_matmul_ref(a, w))))
            out.append({"table": "kernel", "name":
                        f"sa_matmul_{m}x{k}x{n}_b{bm}.{bn}.{bk}",
                        "us_per_call": round(us, 1),
                        "ref_us": round(us_ref, 1),
                        "max_abs_err": f"{err:.2e}"})
    # bit-exact datapath kernel
    a = quantize_np(rng.standard_normal((64, 96)), BF16)
    w = quantize_np(rng.standard_normal((96, 32)), BF16)
    us = _time(lambda a, w: ops.skewed_datapath_matmul(a, w),
               jnp.asarray(a), jnp.asarray(w))
    bit = np.array_equal(
        np.asarray(ops.skewed_datapath_matmul(jnp.asarray(a),
                                              jnp.asarray(w))).view(np.uint32),
        ref.chained_fma_ref(a, w).view(np.uint32))
    out.append({"table": "kernel", "name": "fp_emu_skewed_64x96x32",
                "us_per_call": round(us, 1), "bit_exact_vs_model": bit})
    # quantize kernel
    x = jnp.asarray(rng.standard_normal((1024, 256)).astype(np.float32))
    s = ops.amax_scale(x, "fp8_e4m3")
    us = _time(lambda x: ops.quantize_fp8(x, s, "fp8_e4m3", interpret=True), x)
    out.append({"table": "kernel", "name": "quantize_fp8_e4m3_262k",
                "us_per_call": round(us, 1)})
    return out


def main():
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
