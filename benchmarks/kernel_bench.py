"""Kernel micro-bench: sa_matmul (interpret) vs the jnp reference, the
bit-exact fp_emu datapath kernel, the fp8 quantize kernel — plus the
autotune sweep (tuned vs heuristic block shapes, persisted to the JSON
cache) and an end-to-end backend A/B of `sa_dot` (xla vs pallas vs emulate).

Wall times on this CPU container are interpret-mode numbers (the kernels
target TPU); the point of the table is correctness overhead accounting and
block-shape behaviour, not absolute speed.

``--json PATH`` additionally writes the rows as a JSON document
(conventionally ``BENCH_kernels.json``) that CI uploads as an artifact and
feeds to ``benchmarks/check_bench_regression.py`` against the committed
``benchmarks/BENCH_baseline.json``; ``--smoke`` is the reduced CI
configuration (fewer shapes/reps — regenerate the baseline with the same
flag).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fpformats import BF16, quantize_np
from repro.core.precision import PrecisionPolicy, sa_dot
from repro.kernels import autotune, ops, ref


def _time(fn, *args, reps=3):
    fn(*args).block_until_ready()      # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps * 1e6


def rows(smoke: bool = False):
    rng = np.random.default_rng(0)
    out = []
    gemm_shapes = (((256, 256, 256),) if smoke
                   else ((256, 256, 256), (512, 1024, 512)))
    for m, k, n in gemm_shapes:
        a = jnp.asarray(quantize_np(rng.standard_normal((m, k)), BF16),
                        jnp.bfloat16)
        w = jnp.asarray(quantize_np(rng.standard_normal((k, n)), BF16),
                        jnp.bfloat16)
        us_ref = _time(lambda a, w: ref.sa_matmul_ref(a, w), a, w)
        for bm, bn, bk in ((128, 128, 256), (256, 256, 512)):
            us = _time(lambda a, w: ops.sa_matmul(a, w, bm=bm, bn=bn, bk=bk),
                       a, w)
            err = float(jnp.max(jnp.abs(
                ops.sa_matmul(a, w, bm=bm, bn=bn, bk=bk)
                - ref.sa_matmul_ref(a, w))))
            out.append({"table": "kernel", "name":
                        f"sa_matmul_{m}x{k}x{n}_b{bm}.{bn}.{bk}",
                        "us_per_call": round(us, 1),
                        "ref_us": round(us_ref, 1),
                        "max_abs_err": f"{err:.2e}"})
    # bit-exact datapath kernel
    a = quantize_np(rng.standard_normal((64, 96)), BF16)
    w = quantize_np(rng.standard_normal((96, 32)), BF16)
    us = _time(lambda a, w: ops.skewed_datapath_matmul(a, w),
               jnp.asarray(a), jnp.asarray(w))
    bit = np.array_equal(
        np.asarray(ops.skewed_datapath_matmul(jnp.asarray(a),
                                              jnp.asarray(w))).view(np.uint32),
        ref.chained_fma_ref(a, w).view(np.uint32))
    out.append({"table": "kernel", "name": "fp_emu_skewed_64x96x32",
                "us_per_call": round(us, 1), "bit_exact_vs_model": bit})
    # approximate-normalization datapath (bulk tier): same kernel, coarse LZA
    us = _time(lambda a, w: ops.skewed_datapath_matmul(a, w, mode="approx"),
               jnp.asarray(a), jnp.asarray(w))
    bit = np.array_equal(
        np.asarray(ops.skewed_datapath_matmul(
            jnp.asarray(a), jnp.asarray(w),
            mode="approx")).view(np.uint32),
        ref.chained_fma_ref(a, w, pipeline="approx").view(np.uint32))
    out.append({"table": "kernel", "name": "fp_emu_approx_64x96x32",
                "us_per_call": round(us, 1), "bit_exact_vs_model": bit})
    # quantize kernel
    x = jnp.asarray(rng.standard_normal((1024, 256)).astype(np.float32))
    s = ops.amax_scale(x, "fp8_e4m3")
    us = _time(lambda x: ops.quantize_fp8(x, s, "fp8_e4m3", interpret=True), x)
    out.append({"table": "kernel", "name": "quantize_fp8_e4m3_262k",
                "us_per_call": round(us, 1)})
    out.extend(autotune_rows(smoke))
    out.extend(decode_rows(smoke))
    out.extend(spec_verify_rows(smoke))
    out.extend(decode_attn_rows(smoke))
    out.extend(backend_rows(rng))
    return out


def _tuned_row(table, m, k, n, dtype, reps=2):
    """Sweep one GEMM shape; report tuned vs heuristic-default blocks."""
    default = autotune.default_blocks(m, n, k)
    best, sweep = autotune.tune(m, n, k, dtype=dtype, reps=reps)
    by_blocks = {tuple(r["blocks"]): r["us"] for r in sweep}
    return {"table": table, "name": f"sa_matmul_{m}x{k}x{n}",
            "default_blocks": "x".join(map(str, default)),
            "default_us": round(by_blocks.get(default, float("nan")), 1),
            "tuned_blocks": "x".join(map(str, best)),
            "tuned_us": round(sweep[0]["us"], 1),
            "candidates": len(sweep)}


def autotune_rows(smoke: bool = False):
    """Sweep block shapes per GEMM shape; the winners land in the JSON cache
    (`autotune.cache_path()`), so later processes start tuned."""
    dtype = autotune.production_dtype()
    shapes = (((256, 256, 256),) if smoke
              else ((256, 256, 256), (512, 1024, 512), (384, 256, 640)))
    out = [_tuned_row("autotune", m, k, n, dtype) for m, k, n in shapes]
    out.append({"table": "autotune", "name": "cache",
                "path": autotune.cache_path(),
                "backend": autotune.backend_key()})
    return out


def decode_rows(smoke: bool = False):
    """Decode-shape GEMVs (M ∈ {1, 4, 8}): the per-token serving regime.

    `clip_blocks` rounds these M up to one 16-sublane tile, so the sweep is
    over the (bn, bk) tiling (autotune's DECODE_CANDIDATES); winners land in
    the same JSON cache the engine's decode step reads."""
    dtype = autotune.production_dtype()
    n, k = 512, 256
    ms = (1, 4) if smoke else (1, 4, 8)
    return [_tuned_row("decode", m, k, n, dtype) for m in ms]


def spec_verify_rows(smoke: bool = False):
    """Verify-block GEMMs (M = batch·(spec_k+1)): the self-speculative
    decode verification regime (DESIGN.md §9).

    Deliberately odd Ms — spec_k ∈ {1, 4, 8} at batch 1 gives M ∈ {2, 5, 9},
    between the decode table's power-of-two rows, so `clip_blocks`' sublane
    rounding is exercised off the tile grid. The engine pre-seeds these
    shapes via `autotune.tune_spec_verify`."""
    dtype = autotune.production_dtype()
    n, k = 512, 256
    ms = (2, 5) if smoke else (2, 5, 9)
    return [_tuned_row("spec_verify", m, k, n, dtype) for m in ms]


def _paged_workload(rng, batch, kvh, g, hd, psz, max_pages, mapped):
    """Synthetic paged-pool decode workload: `mapped` of `max_pages` block-
    table columns live per slot, trash page (id 0) poisoned with NaN so any
    masking bug shows up as a non-finite output, not a small error."""
    n_pages = batch * max_pages + 1
    q = jnp.asarray(rng.standard_normal((batch, 1, kvh * g, hd)), jnp.float32)
    k = rng.standard_normal((n_pages, psz, kvh, hd)).astype(np.float32)
    v = rng.standard_normal((n_pages, psz, kvh, hd)).astype(np.float32)
    k[0] = v[0] = np.nan
    pp = np.full((n_pages, psz), -1, np.int32)
    bt = np.full((batch, max_pages), -1, np.int32)
    for b in range(batch):
        pids = 1 + b * max_pages + np.arange(mapped)
        bt[b, :mapped] = pids
        pp[pids] = np.arange(mapped * psz, dtype=np.int32).reshape(mapped,
                                                                   psz)
    pos = jnp.full((batch,), mapped * psz - 1, jnp.int32)
    return (q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(pp),
            jnp.asarray(bt), pos)


def decode_attn_rows(smoke: bool = False):
    """Paged decode attention: fused page-walk kernel vs gather+dense.

    The fused kernel's work scales with the pages actually mapped
    (`pl.when` skips dead block-table columns); the gather path always
    materializes and attends over full block-table capacity. So the sparse
    rows (mapped ≤ 50 % of max_pages — the steady serving regime between
    admissions) are where fused must win; the fully-mapped row is the
    worst case. `bit_equal` pins the two paths u32-identical per row."""
    from repro.models.layers import (PagedKVCache, decode_attention,
                                     gather_pages)

    rng = np.random.default_rng(3)
    # shape picked where the gather path's full-capacity materialize is
    # real work (psz=256 pages): on the CPU interpreter the fused win is
    # 1.2-1.7x across the table; on TPU the gap widens further (the gather
    # path streams B*P*psz rows through HBM, the kernel DMAs pool blocks)
    kvh, g, hd, psz, P = 2, 4, 64, 256, 16
    mapped_counts = (1, 4, 8) if smoke else (1, 2, 4, 8, 16)
    out = []
    for batch in (1, 8):
        blocks, _ = autotune.tune_decode_attn(batch, kvh, g, hd, psz, P,
                                              reps=2)
        for mapped in mapped_counts:
            q, k, v, pp, bt, pos = _paged_workload(rng, batch, kvh, g, hd,
                                                   psz, P, mapped)
            fused = jax.jit(lambda q, k, v, pp, bt, pos: ops.
                            paged_decode_attention(q, k, v, pp, bt, pos))
            gather = jax.jit(lambda q, k, v, pp, bt, pos: decode_attention(
                q, *gather_pages(PagedKVCache(k, v, pp, bt)), pos))
            # min-of-3 passes: these rows sit near the CPU timing noise
            # floor and a single stray scheduler tick flips the verdict
            us_f = min(_time(fused, q, k, v, pp, bt, pos, reps=8)
                       for _ in range(3))
            us_g = min(_time(gather, q, k, v, pp, bt, pos, reps=8)
                       for _ in range(3))
            bit = np.array_equal(
                np.asarray(fused(q, k, v, pp, bt, pos)).view(np.uint32),
                np.asarray(gather(q, k, v, pp, bt, pos)).view(np.uint32))
            out.append({"table": "decode_attn",
                        "name": f"decode_attn_B{batch}_m{mapped}of{P}",
                        "tuned_blocks": "x".join(map(str, blocks)),
                        "tuned_us": round(us_f, 1),
                        "gather_us": round(us_g, 1),
                        "speedup": round(us_g / us_f, 2),
                        "bit_equal": bit})
    return out


def backend_rows(rng):
    """sa_dot A/B: one flag flips the whole stack between backends."""
    out = []
    m, k, n = 128, 256, 128
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    # timing and error describe the same op: the fused-silu sa_dot
    ref_y = np.asarray(sa_dot(a, w, PrecisionPolicy(backend="xla"),
                              act="silu"))
    for backend in ("xla", "pallas"):
        pol = PrecisionPolicy(backend=backend)
        fn = jax.jit(lambda a, w: sa_dot(a, w, pol, act="silu"))
        # the xla row doubles as check_bench_regression's machine-speed
        # reference: min-of-3 passes, or its noise rescales every gated row
        us = min(_time(fn, a, w, reps=8) for _ in range(3))
        err = float(np.max(np.abs(np.asarray(fn(a, w)) - ref_y)))
        out.append({"table": "backend", "name": f"sa_dot_{backend}_{m}x{k}x{n}",
                    "us_per_call": round(us, 1), "max_abs_err_vs_xla":
                    f"{err:.2e}"})
    # emulate: tiny shape (pure-python bit-exact model, O(MKN) in numpy)
    ae, we = a[:16, :32], w[:32, :16]
    pol = PrecisionPolicy(backend="emulate")
    us = _time(lambda a, w: sa_dot(a, w, pol), ae, we)
    out.append({"table": "backend", "name": "sa_dot_emulate_16x32x16",
                "us_per_call": round(us, 1)})
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (e.g. BENCH_kernels.json) "
                         "for CI artifacts / the regression checker")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CI configuration: fewer shapes and decode "
                         "Ms (baseline must be generated with the same flag)")
    args = ap.parse_args(argv)
    out = rows(smoke=args.smoke)
    for r in out:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    if args.json:
        payload = {"version": 1, "smoke": args.smoke,
                   "backend": autotune.backend_key(),
                   "dtype": autotune.production_dtype(),
                   "jax": jax.__version__, "rows": out}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json} ({len(out)} rows)")
    return out


if __name__ == "__main__":
    main()
