"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh),
derived from the dry-run artifacts (cost_analysis + HLO collective parse).

TPU v5e constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. `cost_analysis()` on the SPMD-partitioned module reports **per-device**
FLOPs/bytes; the parsed collective payloads are per-device payload proxies
(max tensor per collective op ≈ ring payload). Terms are therefore computed
per device without re-dividing by chip count:

    compute_s    = flops_dev / 197e12
    memory_s     = bytes_dev / 819e9
    collective_s = coll_bytes_dev / 50e9

MODEL_FLOPS uses 6·N_active·tokens (train) / 2·N_active·tokens (serve);
the ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/recompute waste.
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.models.config import SHAPES_BY_NAME

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

# Activation materialization passes per layer (audited against the per-layer
# op inventory of the compiled HLO: ~15 residual-width tensors fwd, ~22 bwd,
# ~8 remat re-forward).
ACT_PASSES = {"train": 45, "prefill": 15, "decode": 20}


def analytic_bytes_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    """HBM traffic model (bytes/device/step).

    The CPU-compiled HLO cannot give TPU-faithful HBM traffic (different
    fusion granularity, hoisting artifacts inside scan bodies — see
    EXPERIMENTS.md §Roofline); this explicit model counts: optimizer state
    r/w (train), bf16 weight reads per pass, residual-width activation
    materializations, attention score/probability tiles (our flash attention
    is jnp-level: p tiles do hit HBM), and KV/state cache traffic (decode).
    """
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    P = cfg.param_count()
    L, d, T, GB = cfg.num_layers, cfg.d_model, shape.seq_len, shape.global_batch
    H = max(cfg.num_heads, cfg.n_ssm_heads if cfg.attn_free else cfg.num_heads)
    # attention head sharding efficiency: replicated when KVH doesn't divide
    # the 16-way model axis (this is also visible as the FLOPs inflation)
    tp = 16
    heads_eff = H / tp if (cfg.num_kv_heads % tp == 0) else H
    tokens_dev = GB * T / n_dev

    if shape.kind == "train":
        opt = 28.0 * P / n_dev                       # 7 fp32 quantities r/w
        wts = 3.0 * 2.0 * P / n_dev * 1.0            # bf16 fwd+dgrad+wgrad
        act = ACT_PASSES["train"] * L * tokens_dev * d * 2.0
        attn_p = 0.0
        if not cfg.attn_free:
            attn_p = 5.0 * L * (GB / n_dev) * heads_eff * T * T * 4.0
        return opt + wts + act + attn_p
    if shape.kind == "prefill":
        wts = 2.0 * P / n_dev
        act = ACT_PASSES["prefill"] * L * tokens_dev * d * 2.0
        attn_p = 0.0
        if not cfg.attn_free:
            attn_p = 1.0 * L * (GB / n_dev) * heads_eff * T * T * 4.0
        return wts + act + attn_p
    # decode: weights (active experts only) + cache read + small activations
    wts = 2.0 * cfg.active_param_count() / n_dev
    cache = 0.0
    if not cfg.attn_free:
        for i in range(L):
            kind = cfg.layer_kind(i)
            S = min(cfg.window, T) if kind["attn"] == "local" else T
            cache += 2 * GB * S * cfg.num_kv_heads * cfg.hd * 2.0
    if cfg.attn_free or cfg.hybrid:
        cache += (GB * cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
                  * 4.0 * 2 * L)
    act = ACT_PASSES["decode"] * L * (GB / n_dev) * d * 2.0
    return wts + cache / n_dev + act


def model_flops_per_device(arch: str, shape_name: str, n_dev: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_dev
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch / n_dev


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    n_dev = rec["n_devices"]
    flops = rec.get("flops", 0.0)          # trip-count-aware HLO dot count
    byts = analytic_bytes_per_device(rec["arch"], rec["shape"], n_dev)
    byts_hlo = rec.get("bytes_accessed", 0.0)   # CPU-fusion upper bound
    coll = rec.get("collectives", {}).get("total", 0.0)
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_n = coll / ICI_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_n, "collective"))[1]
    mf = model_flops_per_device(rec["arch"], rec["shape"], n_dev)
    t_total = max(t_c, t_m, t_n)
    # roofline fraction: useful-model-FLOPs time over the modeled step time
    frac = (mf / PEAK_FLOPS) / t_total if t_total > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "memory_s_hlo_upper": byts_hlo / HBM_BW,
        "bottleneck": dom,
        "model_flops_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": frac,
        "peak_gib": rec.get("memory", {}).get("peak_estimate_bytes", 0) / 2**30,
    }


def all_rows(mesh: str | None = "pod"):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        rec = json.load(open(f))
        if mesh and rec.get("mesh") != mesh:
            continue
        r = analyze(rec)
        if r:
            rows.append(r)
    return rows


def main():
    rows = all_rows("pod")
    if not rows:
        print("# no dry-run artifacts found — run repro.launch.dryrun first")
        return
    rows.sort(key=lambda r: r["roofline_frac"])
    for r in rows:
        print(f"table=roofline,arch={r['arch']},shape={r['shape']},"
              f"compute_s={r['compute_s']:.2e},memory_s={r['memory_s']:.2e},"
              f"collective_s={r['collective_s']:.2e},"
              f"bottleneck={r['bottleneck']},"
              f"useful_ratio={r['useful_ratio']:.3f},"
              f"roofline_frac={r['roofline_frac']:.3f},"
              f"peak_gib={r['peak_gib']:.2f}")
    worst = rows[0]
    coll_bound = max(rows, key=lambda r: r["collective_s"]
                     / max(r["compute_s"], 1e-12))
    print(f"# worst roofline fraction: {worst['arch']}×{worst['shape']} "
          f"({worst['roofline_frac']:.3f})")
    print(f"# most collective-bound: {coll_bound['arch']}×{coll_bound['shape']}")


if __name__ == "__main__":
    main()
