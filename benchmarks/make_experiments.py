"""Render EXPERIMENTS.md from dry-run artifacts + paper benchmarks.

    PYTHONPATH=src:. python benchmarks/make_experiments.py
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import roofline as RL
from repro.core import energy as E

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
BASE = os.path.join(ROOT, "artifacts", "dryrun")
OPT = os.path.join(ROOT, "artifacts", "dryrun_opt")
HC = os.path.join(ROOT, "artifacts", "hillclimb")


def load(d):
    out = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def dryrun_matrix(cells, mesh_note=True):
    archs = sorted({k[0] for k in cells})
    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    lines = ["| arch | " + " | ".join(shapes) + " |",
             "|---|" + "---|" * len(shapes)]
    for a in archs:
        row = [a]
        for s in shapes:
            pod = cells.get((a, s, "pod"), {})
            mp = cells.get((a, s, "multipod"), {})
            st = pod.get("status", "—")
            if st == "ok":
                mark = "✓✓" if mp.get("status") == "ok" else "✓·"
                row.append(
                    f"{mark} {pod['memory'].get('peak_estimate_bytes',0)/2**30:.1f}G")
            elif st == "skipped":
                row.append("n/a")
            else:
                row.append("**ERR**")
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def roofline_table(cells, mesh="pod"):
    rows = []
    for k in sorted(cells):
        if k[2] != mesh:
            continue
        a = RL.analyze(cells[k])
        if a:
            rows.append(a)
    rows.sort(key=lambda a: -a["roofline_frac"])
    head = ("| arch | shape | compute s | memory s | collective s | "
            "bottleneck | MODEL/HLO | roofline | peak GiB |\n"
            "|---|---|---|---|---|---|---|---|---|")
    body = "\n".join(
        f"| {a['arch']} | {a['shape']} | {a['compute_s']:.2e} | "
        f"{a['memory_s']:.2e} | {a['collective_s']:.2e} | {a['bottleneck']} | "
        f"{a['useful_ratio']:.2f} | {a['roofline_frac']:.3f} | "
        f"{a['peak_gib']:.1f} |" for a in rows)
    return head + "\n" + body


def compare_rows(tag_recs):
    head = ("| variant | HLO flops/dev | compute s | coll GiB | coll s | "
            "peak GiB | roofline |\n|---|---|---|---|---|---|---|")
    lines = [head]
    for tag, rec in tag_recs:
        if rec is None:
            lines.append(f"| {tag} | (pending) | | | | | |")
            continue
        a = RL.analyze(rec)
        coll = rec["collectives"]["total"] / 2**30
        lines.append(
            f"| {tag} | {rec['flops']:.2e} | {a['compute_s']:.2f} | "
            f"{coll:.0f} | {a['collective_s']:.2f} | {a['peak_gib']:.1f} | "
            f"{a['roofline_frac']:.3f} |")
    return "\n".join(lines)


TEMPLATE = """# EXPERIMENTS

All numbers regenerate with:
`PYTHONPATH=src python -m repro.launch.dryrun --all` (dry-run artifacts),
`PYTHONPATH=src:. python -m benchmarks.run` (paper tables + roofline),
`PYTHONPATH=src:. python benchmarks/make_experiments.py` (this file).

## §Paper-claims — faithful reproduction (the baseline; paper §IV)

Cycle-accurate SA model (128×128 WS array @ 1 GHz, Bfloat16 in / FP32
reduction), both pipelines; energy = per-cycle (area-scaled) + per-MAC
components (85/15 split, `core/energy.py`).

| metric | paper | ours | status |
|---|---|---|---|
| MobileNet latency saving | 16 % | {MB_LAT} | ✓ (±4 pp gate in tests) |
| MobileNet energy saving | 8 % | {MB_EN} | ✓ |
| ResNet50 latency saving | 21 % | {RN_LAT} | ✓ |
| ResNet50 energy saving | 11 % | {RN_EN} | ✓ (+3 pp: uniform-power model; paper had per-layer measured power) |
| area overhead | +9 % | +9 % (constant, §IV) | ✓ |
| power overhead | +7 % | +7 % (constant, §IV) | ✓ |
| skew ≡ baseline bit-exactness | implied §III.B | exact, all formats (hypothesis, 300+ cases) | ✓ |

Per-layer trends (Figs. 7/8) reproduce: early layers lose energy (latency
gain < +7 % power), late layers save up to ~25 % — see
`benchmarks/paper_latency_energy.py` output in `bench_output.txt`.
Depthwise-mapping sensitivity (the paper under-specifies it): packed
block-diagonal (default) −17.1 % latency; per-channel −3.2 %; offloaded
−20.6 % — the paper's −16 % sits inside this band at our default.

## §Dry-run — 40 cells × (pod 16×16=256 chips, multipod 2×16×16=512 chips)

{N_OK} cells compile on both meshes; {N_SKIP} cells are documented skips
(`long_500k` × pure full-attention archs, DESIGN.md §5). ✓✓ = pod+multipod
compile OK; number = peak bytes/device from `memory_analysis()` (pod mesh,
donated buffers). Every cell record (memory, FLOPs, per-class collective
payloads, compile times) lives in `artifacts/dryrun/*.json`.

{MATRIX}

Fit notes (v5e = 16 GB HBM/chip): serving and ≤3 B-param training cells fit
a single pod. 9–14 B `train_4k` cells need activation-side tuning or more
chips (peak 32–50 GiB at batch 256×4096 — batch/chip on a real job would be
chosen per HBM). llama4-maverick training is a v5p/multi-pod workload by
construction (§Perf hillclimb 2 quantifies the memory↔collective tradeoff).
The multipod mesh proves the `pod` axis shards: llama4 state drops from
21.7 GB/dev (pod) to 10.9 GB/dev (multipod, FSDP over pod×data).

## §Roofline — per-cell terms (pod mesh, per device)

Constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI
(single-link — conservative; v5e rings use 2+ links).
Sources: FLOPs + collective payloads from the **trip-count-aware HLO
analyzer** (`launch/hlo_cost.py` — XLA's own `cost_analysis()` counts scan
bodies once, up to 320× under; the analyzer is validated exact on unit
programs). Memory term from the explicit traffic model in
`benchmarks/roofline.py` (CPU-backend HLO has different fusion granularity
than TPU, so measured bytes are kept only as an upper bound —
`memory_s_hlo_upper` in the artifacts). `MODEL/HLO` = 6·N_active·tokens
(train) or 2·N_active·tokens (serve) over analyzer FLOPs — the useful-work
fraction of compiled compute; `roofline` = useful-FLOPs time / dominant
term (the score axis).

### Baseline (paper-faithful framework, no beyond-paper sharding fixes)

{ROOFLINE_BASE}

Reading the table: at TP=16 every train cell is **collective-bound** —
dominated by fp32 FSDP/TP traffic and, for non-divisible head counts,
attention replication (phi3 MODEL/HLO 0.38 = 2.6× wasted compute). Decode
cells are collective-bound through per-step KV-cache resharding. These are
the three hillclimb targets.

### Optimized (beyond-paper: padded-KV-head TP + bf16 param gathers)

{ROOFLINE_OPT}

## §Perf — hypothesis → change → measure log

Three cells hillclimbed (worst useful-ratio train, most collective-bound
serving, most paper-representative 400 B GEMM volume). Baseline = the
faithful framework above; every change is flag-gated
(`repro/core/optflags.py`) so both lowerings ship.

### Hillclimb 1 — phi3-medium-14b × train_4k (worst MODEL/HLO ratio)

*Hypothesis:* MODEL/HLO = 0.38 means 2.6× the useful FLOPs are compiled.
phi3 has 40 Q / 10 KV heads; 10 ∤ 16 ⇒ the partitioner must **replicate
every attention einsum across the model axis** (16×). Napkin: attention is
~11 % of forward FLOPs; 16× replication ⇒ ~2.6× total. Predicted fix: pad
KV heads 10→16 (zeros), Q heads 40→64 (kv-major layout keeps GQA mapping),
slice outputs — ≤1.6× attention overhead instead of 16×.

*Change:* `optflags.pad_kv_heads` (layers.py `_pad_heads` + sharding
constraints).

{HC1}

*Result:* **confirmed** — FLOPs/dev 9.52e14 → 4.69e14 (−51 %), MODEL/HLO
0.38 → 1/1.30 ≈ 0.77 (remaining 1.30× = flash-attention backward recompute
+ padded-head waste). Compute term halves; bottleneck shifts fully to the
fp32 TP all-reduces (210 GiB/step — next lever, see "next steps").

*Iteration 1b (refinement, refuted-then-fixed):* applying the same padding
+ forced head sharding to **all** archs regressed the train cells whose
heads already shard cleanly (gemma2 train roofline 0.215→0.158: the pad
itself and the layout constraint add reshard permutes where XLA's own
fused-dim layout was already collective-free). Fix: `pad_attn_train` is a
per-arch policy knob (on for phi3/qwen where the baseline replicates; off
elsewhere), and the layout constraints engage only when padding is active —
after which every train cell ≥ baseline (gemma2/3, whisper, hymba exactly
recover the baseline lowering; qwen train roofline 0.067→0.165, collectives
27.5 s→11.2 s). A refuted hypothesis made the rule *conditional* — that
rule is itself a measured result. Final per-arch policy (all measured):
`pad_attn_train=True` for phi3, qwen2.5, granite (18.1→10.9 s train
collectives), llama4; off for gemma2/3, pixtral, whisper, hymba, mamba2.

*Metric note (phi3):* the roofline *fraction* for phi3 train dips
(0.264→0.233) because the conservative single-link collective term grows
13 % while compute halves; with ≥2 ICI links (real v5e rings) the
collective term halves and the padded variant strictly wins. The compute
saving (−4.9e14 FLOPs/dev/step) is unconditional.

### Hillclimb 2 — llama4-maverick-400b × train_4k (paper-representative)

*Hypothesis A:* FSDP all-gathers move **fp32** master weights; casting
params to bf16 at superblock entry halves gather payloads with bit-identical
numerics (sa_dot quantizes to bf16 at use anyway).
*Change A:* `optflags.bf16_params_in_layers`.
*Hypothesis B:* weight gathers scale with µbatch count (re-gather per
microstep, ×2 for remat re-forward). accum 8→2 should cut gather traffic
~4× at the cost of 4× activation memory.
*Change B:* `--accum 2`.

{HC2}

| iteration | all-gather GiB | total coll GiB | peak GiB |
|---|---|---|---|
| baseline (fp32 gathers, accum 8) | {L4_BASE_AG} | {L4_BASE_T} | {L4_BASE_P} |
| + bf16 gathers (A) | 2815 | 4420 | 78.0 |
| + accum 2 (B) | 1161 | 2584 | 144.1 |

*Result:* A **confirmed** (gathers halve). B **confirmed with tradeoff** —
−59 % gathers, −42 % total collectives, +85 % peak HBM: the dry-run
quantifies the accum↔memory operating curve; at v5e HBM neither end fits
256 chips (llama4 train is a v5p/2-pod workload — multipod state is
10.9 GB/dev), so the deployed point picks accum per HBM budget.

### Hillclimb 3 — gemma3-12b × decode_32k (most collective-bound serving)

*Hypothesis:* decode collectives (0.40 s/token ⇒ unusable) come from
resharding the hd-sharded KV cache to the head-sharded attention layout
**every step** (the SPMD "involuntary full rematerialization" warning; the
whole cache moves per token). Padding KV heads 8→16 lets cache storage and
attention compute share one head-sharded layout — predicted: collectives
drop to per-layer logits/TP reductions (MB-scale), at 2× KV-cache memory.

*Change:* same `pad_kv_heads` + head-sharded cache specs
(`cache_specs`, `init_cache(kv_pad_to=16)`).

{HC3}

*Result:* **confirmed, 90×** — collective payload 2.5 GiB → 28 MiB per
decode step; bottleneck flips to weights/cache HBM reads (the natural
decode regime). Cost: padded cache doubles KV bytes (11.9 → 15.5 GiB peak);
acceptable against a 90× ICI saving — and the step-time model improves
~20× (0.40 s → ~0.02 s memory-bound).

### Stopping criterion & next steps

One further iteration was implemented and **refuted** (kept in-tree,
default-off — `optflags.pad_experts`): padding granite's expert dim 40→48
at *trace time* to switch MoE dispatch from TP-inside-expert to EP. Measured
+104 % collectives (10.9 s → 22.3 s): the stored weights are F-sharded, so
the padded compute layout forces a full expert-weight reshard per layer per
µstep — the reshard costs more than the dispatch it saves. The correct
version stores parameters E-padded (a checkpoint-shape change), recorded as
the production follow-up. A refuted hypothesis with a measured mechanism is
as informative as a win.

Two more candidates napkin-mathed but not implemented:
1. bf16 TP activation all-reduces (phi3: 210→105 GiB, −2 s) — needs a
   shard_map TP path because XLA cannot legally commute convert with psum;
   deviates from the SA contract at chip boundaries (rounding at the
   chip-edge instead of column end) — a documented contract trade.
2. Megatron-style sequence parallelism on the norm/residual segments
   (same bytes, overlappable under compute).

## §Perf — paper-baseline vs beyond-paper summary

The paper's technique (reduced-precision chained accumulation) is
arithmetic-level and carries zero distributed overhead; the faithful
baseline's inefficiencies were all in *our* distribution layer, and the
beyond-paper fixes recover: −51 % compiled FLOPs (phi3-class archs),
−42 % collective payload (llama4 train), −99 % decode collectives
(gemma3-class serving). Both lowerings remain available
(`REPRO_OPT=0` reproduces the baseline exactly).
"""


def main():
    base = load(BASE)
    opt = load(OPT)
    mb = E.network_totals("mobilenet")
    rn = E.network_totals("resnet50")
    n_ok = sum(1 for r in base.values() if r["status"] == "ok")
    n_skip = sum(1 for r in base.values() if r["status"] == "skipped")

    l4b = base.get(("llama4-maverick-400b-a17b", "train_4k", "pod"))

    def hc_tbl(cell):
        return compare_rows([("baseline", base.get(cell)),
                             ("optimized", opt.get(cell))])

    out = TEMPLATE.format(
        MB_LAT=f"{mb['latency_saving']:.1%}", MB_EN=f"{mb['energy_saving']:.1%}",
        RN_LAT=f"{rn['latency_saving']:.1%}", RN_EN=f"{rn['energy_saving']:.1%}",
        N_OK=n_ok, N_SKIP=n_skip,
        MATRIX=dryrun_matrix(base),
        ROOFLINE_BASE=roofline_table(base),
        ROOFLINE_OPT=roofline_table(opt) if opt else "(run the optimized sweep)",
        HC1=hc_tbl(("phi3-medium-14b", "train_4k", "pod")),
        HC2=hc_tbl(("llama4-maverick-400b-a17b", "train_4k", "pod")),
        HC3=hc_tbl(("gemma3-12b", "decode_32k", "pod")),
        L4_BASE_AG=f"{l4b['collectives'].get('all-gather', 0)/2**30:.0f}"
        if l4b else "?",
        L4_BASE_T=f"{l4b['collectives']['total']/2**30:.0f}" if l4b else "?",
        L4_BASE_P=f"{l4b['memory'].get('peak_estimate_bytes',0)/2**30:.1f}"
        if l4b else "?",
    )
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path, "w") as f:
        f.write(out)
    print(f"wrote {path} ({len(out)} chars)")


if __name__ == "__main__":
    main()
