"""Benchmark orchestrator: one section per paper table/figure + the roofline
report. Prints ``name,us_per_call,derived`` style CSV lines per section."""
from __future__ import annotations

import time


def _section(name, fn):
    print(f"## {name}")
    t0 = time.time()
    fn()
    print(f"## {name} done in {time.time()-t0:.1f}s\n")


def main() -> None:
    from benchmarks import (ablations, kernel_bench, paper_area_power,
                            paper_latency_energy, roofline)
    _section("paper_latency_energy (Figs 7-8, §IV headline)",
             paper_latency_energy.main)
    _section("paper_area_power (§IV synthesis)", paper_area_power.main)
    _section("ablations (array size / format / batch)", ablations.main)
    _section("kernel_bench (Pallas interpret)", kernel_bench.main)
    _section("roofline (from dry-run artifacts)", roofline.main)


if __name__ == "__main__":
    main()
