"""Fail CI when a tuned GEMM latency regresses against the committed
baseline.

    python benchmarks/check_bench_regression.py BENCH_kernels.json \
        benchmarks/BENCH_baseline.json --rtol 0.2

Compares the ``tuned_us`` column of the ``autotune``, ``decode``,
``spec_verify`` and ``decode_attn`` tables (the tuned SA-GEMM /
decode-GEMV / speculative-verify-block latencies and the fused paged
decode-attention kernel) row by row against the baseline.
Interpret-mode wall times vary with runner speed, so by default
each ratio is normalized by a **machine-speed reference outside the
compared set**: the ``backend`` table's ``sa_dot_xla_*`` row (a plain
lax.dot_general timing the SA kernels can't regress). A uniformly slower
runner scores 1.0 everywhere, while a kernel change that slows *all* the
tuned rows still stands out against the unchanged XLA reference. If the
reference row is missing from either file it falls back to the median
new/base ratio of the compared rows (which can only catch regressions
hitting a minority of rows). Disable with ``--no-normalize`` when both
files come from the same machine. Noisier tables can carry a wider
per-table tolerance (``RTOL_BY_TABLE``); ``--rtol`` raises but never
tightens those.

Exit codes: 0 ok, 1 regression, 2 usage/schema error.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys

COMPARED_TABLES = ("autotune", "decode", "spec_verify", "decode_attn")
REFERENCE_TABLE, REFERENCE_PREFIX = "backend", "sa_dot_xla_"
# interpret-mode attention rows (B unrolled pallas calls, ms-scale) drift
# more run-to-run than the GEMM microbenches; gate them looser so the
# check catches real slowdowns without tripping on scheduler noise. The
# spec_verify rows are small off-tile GEMMs (M ∈ {2, 5, 9}) closer to the
# timing noise floor than the decode GEMVs, so they get a middle tolerance.
RTOL_BY_TABLE = {"decode_attn": 0.4, "spec_verify": 0.3}


def load_rows(path: str) -> tuple[dict[tuple[str, str], float], float | None]:
    """→ ({(table, name): tuned_us}, reference_us-or-None)."""
    with open(path) as f:
        doc = json.load(f)
    rows, ref = {}, None
    for r in doc.get("rows", []):
        if r.get("table") in COMPARED_TABLES and "tuned_us" in r:
            rows[(r["table"], r["name"])] = float(r["tuned_us"])
        elif (r.get("table") == REFERENCE_TABLE
              and str(r.get("name", "")).startswith(REFERENCE_PREFIX)
              and "us_per_call" in r):
            ref = float(r["us_per_call"])
    if not rows:
        print(f"no comparable rows (tables {COMPARED_TABLES} with "
              f"tuned_us) in {path}", file=sys.stderr)
        sys.exit(2)
    return rows, ref


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="fresh BENCH_kernels.json")
    ap.add_argument("baseline", help="committed benchmarks/BENCH_baseline.json")
    ap.add_argument("--rtol", type=float, default=0.2,
                    help="allowed fractional regression (0.2 = +20%%)")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare raw wall times (same-machine runs only)")
    args = ap.parse_args(argv)

    new, new_ref = load_rows(args.new)
    base, base_ref = load_rows(args.baseline)
    common = sorted(set(new) & set(base))
    if not common:
        print("no overlapping rows between new run and baseline",
              file=sys.stderr)
        return 2
    for missing in sorted(set(base) - set(new)):
        print(f"WARN: baseline row {missing} absent from new run")

    ratios = {k: new[k] / base[k] for k in common if base[k] > 0}
    if args.no_normalize:
        scale = 1.0
    elif new_ref and base_ref:
        scale = new_ref / base_ref
        print(f"machine-speed reference ({REFERENCE_TABLE}/"
              f"{REFERENCE_PREFIX}*): {base_ref:.1f}us -> {new_ref:.1f}us")
    else:
        scale = statistics.median(ratios.values())
        print("WARN: no xla reference row in both files; normalizing by "
              "the median compared ratio (blind to regressions hitting "
              "most rows)")
    bad = []
    for key, ratio in sorted(ratios.items()):
        norm = ratio / scale
        rtol = max(args.rtol, RTOL_BY_TABLE.get(key[0], args.rtol))
        flag = "REGRESSED" if norm > 1.0 + rtol else "ok"
        print(f"{flag:9s} {key[0]}/{key[1]}: {base[key]:.1f}us -> "
              f"{new[key]:.1f}us (x{ratio:.2f}, normalized x{norm:.2f}, "
              f"rtol +{rtol:.0%})")
        if norm > 1.0 + rtol:
            bad.append(key)
    print(f"machine-speed scale: x{scale:.2f} over {len(ratios)} rows "
          f"(threshold +{args.rtol:.0%})")
    if bad:
        print(f"FAIL: {len(bad)} tuned-GEMM row(s) regressed beyond "
              f"+{args.rtol:.0%}: {['/'.join(k) for k in bad]}",
              file=sys.stderr)
        return 1
    print("bench regression check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
