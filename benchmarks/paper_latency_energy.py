"""Paper Figs. 7 & 8 + §IV headline: per-layer and total latency/energy of
MobileNet and ResNet50 on the 128×128 SA, baseline vs skewed pipeline."""
from __future__ import annotations

from repro.core import energy as E

PAPER = {"mobilenet": {"latency": 0.16, "energy": 0.08},
         "resnet50": {"latency": 0.21, "energy": 0.11}}


def rows():
    out = []
    for net in ("mobilenet", "resnet50"):
        reps = E.network_report(net)
        for r in reps:
            out.append({
                "table": f"fig7/8:{net}", "layer": r.layer,
                "cycles_base": r.cycles_base, "cycles_skew": r.cycles_skew,
                "energy_base_uj": round(r.energy_base, 3),
                "energy_skew_uj": round(r.energy_skew, 3),
                "energy_saving_pct": round(100 * r.energy_saving, 2),
            })
        t = E.network_totals(net)
        out.append({
            "table": f"headline:{net}", "layer": "TOTAL",
            "latency_saving_pct": round(100 * t["latency_saving"], 2),
            "paper_latency_pct": 100 * PAPER[net]["latency"],
            "energy_saving_pct": round(100 * t["energy_saving"], 2),
            "paper_energy_pct": 100 * PAPER[net]["energy"],
        })
        # sensitivity to the depthwise mapping (paper under-specifies it)
        for mode in ("per_channel", "offload"):
            tm = E.network_totals(net, dw_mode=mode)
            out.append({
                "table": f"dw-sensitivity:{net}", "layer": f"TOTAL[{mode}]",
                "latency_saving_pct": round(100 * tm["latency_saving"], 2),
                "energy_saving_pct": round(100 * tm["energy_saving"], 2),
            })
    return out


def main():
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))
    for net in ("mobilenet", "resnet50"):
        t = E.network_totals(net)
        ok_l = abs(t["latency_saving"] - PAPER[net]["latency"]) < 0.04
        ok_e = abs(t["energy_saving"] - PAPER[net]["energy"]) < 0.04
        print(f"# {net}: latency {t['latency_saving']:.1%} "
              f"(paper {PAPER[net]['latency']:.0%}, {'OK' if ok_l else 'OFF'}), "
              f"energy {t['energy_saving']:.1%} "
              f"(paper {PAPER[net]['energy']:.0%}, {'OK' if ok_e else 'OFF'})")


if __name__ == "__main__":
    main()
