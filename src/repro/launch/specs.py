"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

`input_specs(cfg, shape)` returns weak-type-correct abstract inputs for the
step function the cell lowers (`train_step` / `prefill` / `serve_step`), and
`cell_shardings(...)` the matching NamedSharding pytrees — no allocation
anywhere (the dry-run contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig, ShapeCfg
from repro.models import model as M
from repro.parallel import sharding as S
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import make_train_step, make_prefill_step, make_serve_step
from repro.train.train_state import abstract_state


def default_optimizer(total_steps: int = 100_000) -> AdamW:
    return AdamW(schedule=warmup_cosine(3e-4, 2000, total_steps))


def accum_steps_for(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh) -> int:
    """Microbatch count: target ≈2 sequences per DP group per microstep."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in S.batch_axes(mesh):
        dp *= sizes[a]
    per_dp = max(1, shape.global_batch // dp)
    return max(1, per_dp // 2)


def _frontend_spec(cfg: ArchConfig, batch: int):
    if cfg.family == "vlm":
        return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.d_model),
                                    jnp.float32)
    if cfg.is_encdec:
        return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.d_model),
                                    jnp.float32)
    return None


DEFAULT_PAGE_SIZE = 64


def pool_pages_for(mesh: Mesh, batch: int, seq_len: int,
                   page_size: int) -> int:
    """Page-pool size for a decode cell: dense-ring-equivalent capacity
    plus the trash page, rounded up so the page dim splits evenly over the
    DP axes (explicit shardings replicate dims they don't divide)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in S.batch_axes(mesh):
        dp *= sizes[a]
    n = -(-batch * seq_len // page_size) + 1
    return -(-n // dp) * dp


def input_specs(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                accum: int | None = None, kv_layout: str = "ring",
                page_size: int = DEFAULT_PAGE_SIZE):
    """→ (step_fn, abstract_args: tuple, in_shardings, out_shardings).

    `kv_layout="paged"` lowers decode cells against the paged KV pool
    (global page pool + block table, pages sharded over the data axes —
    parallel/sharding.cache_specs) instead of per-slot dense rings."""
    GB, T = shape.global_batch, shape.seq_len
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = default_optimizer()
        accum = accum or accum_steps_for(cfg, shape, mesh)
        step_fn = make_train_step(cfg, opt, accum_steps=accum)
        state = abstract_state(cfg, opt)
        batch = {"tokens": jax.ShapeDtypeStruct((GB, T), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((GB, T), jnp.int32)}
        fe = _frontend_spec(cfg, GB)
        if fe is not None:
            batch["frontend"] = fe
        pshard = S.param_shardings(cfg, state.params, mesh)
        state_shard = type(state)(step=repl, params=pshard,
                                  opt_state=type(state.opt_state)(
                                      count=repl, mu=pshard, nu=pshard))
        dshard = {k: NamedSharding(mesh, S.data_specs(mesh, v.shape))
                  for k, v in batch.items()}
        metrics_shard = {k: repl for k in
                         ("loss", "nll", "grad_norm", "lr")}
        return (step_fn, (state, batch), (state_shard, dshard),
                (state_shard, metrics_shard))

    # serving cells: bf16 params; cache KV heads padded to the TP axis
    params = M.abstract_params(cfg, dtype=jnp.bfloat16)
    pshard = S.param_shardings(cfg, params, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if kv_layout not in ("ring", "paged"):
        raise ValueError(f"kv_layout={kv_layout!r}; want 'ring' or 'paged'")
    paged = None
    if kv_layout == "paged" and shape.kind == "decode":
        paged = (pool_pages_for(mesh, GB, T, page_size), page_size)
    cache = M.init_cache(cfg, GB, T, dtype=jnp.bfloat16, abstract=True,
                         kv_pad_to=sizes.get("model", 1), paged=paged)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          S.cache_specs(cfg, cache, mesh, GB))
    fe = _frontend_spec(cfg, GB)
    fe_shard = None if fe is None else NamedSharding(
        mesh, S.data_specs(mesh, fe.shape))

    if shape.kind == "prefill":
        step_fn = make_prefill_step(cfg)
        tokens = jax.ShapeDtypeStruct((GB, T), jnp.int32)
        tshard = NamedSharding(mesh, S.data_specs(mesh, tokens.shape))
        args = (params, tokens, cache) + ((fe,) if fe is not None else ())
        in_sh = (pshard, tshard, cshard) + ((fe_shard,) if fe is not None else ())
        logits_shard = NamedSharding(mesh, S.data_specs(mesh, (GB, 1, 1)))
        return step_fn, args, in_sh, (logits_shard, cshard)

    if shape.kind == "decode":
        step_fn = make_serve_step(cfg)
        token = jax.ShapeDtypeStruct((GB, 1), jnp.int32)
        tshard = NamedSharding(mesh, S.data_specs(mesh, token.shape))
        # per-slot positions (continuous batching): one int32 per batch row,
        # sharded with the batch like the token ids
        pos = jax.ShapeDtypeStruct((GB,), jnp.int32)
        pos_shard = NamedSharding(mesh, S.data_specs(mesh, pos.shape))
        args = (params, token, cache, pos) + ((fe,) if fe is not None else ())
        in_sh = ((pshard, tshard, cshard, pos_shard)
                 + ((fe_shard,) if fe is not None else ()))
        logits_shard = NamedSharding(mesh, S.data_specs(mesh, (GB, 1, 1)))
        return step_fn, args, in_sh, (logits_shard, cshard)

    raise ValueError(shape.kind)


def handoff_specs(cfg: ArchConfig, shape: ShapeCfg, mesh: Mesh,
                  page_size: int = DEFAULT_PAGE_SIZE):
    """Two-pool lowering (DESIGN.md §10): the KV-page handoff program —
    page scatter + block-table bind (`ServeEngine._insert_impl`; the
    disaggregated engine runs the same two halves split across pools) —
    lowered against the decode cell's paged pool. Dry-run's honest answer
    to "what does one handoff cost on this mesh": the batch-1 fragment
    arrives replicated over the data axes
    (parallel/sharding.handoff_frag_specs), so the scatter keeps each
    data shard's pages local and collectives stay O(fragment), never
    O(pool). → (step_fn, abstract_args, in_shardings, out_shardings)."""
    from repro.serve.engine import ServeEngine
    GB, T = shape.global_batch, shape.seq_len
    repl = NamedSharding(mesh, P())
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pool = pool_pages_for(mesh, GB, T, page_size)
    cache = M.init_cache(cfg, GB, T, dtype=jnp.bfloat16, abstract=True,
                         kv_pad_to=sizes.get("model", 1),
                         paged=(pool, page_size))
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          S.cache_specs(cfg, cache, mesh, GB))
    # staging fragment: one full-length prompt, page-quantized
    cap = -(-T // page_size) * page_size
    frag = M.init_cache(cfg, 1, cap, dtype=jnp.bfloat16, abstract=True,
                        kv_pad_to=sizes.get("model", 1))
    fshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          S.handoff_frag_specs(cfg, frag, mesh))
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    block_row = jax.ShapeDtypeStruct((cap // page_size,), jnp.int32)
    keep = jax.ShapeDtypeStruct((), jnp.int32)
    args = (cache, frag, slot, block_row, keep)
    in_sh = (cshard, fshard, repl, repl, repl)
    return ServeEngine._insert_impl, args, in_sh, cshard


def cell_is_applicable(cfg: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """DESIGN.md §5: long_500k is skipped for pure full-attention archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode cell skipped "
                       "(DESIGN.md §5)")
    return True, ""
