"""Training driver: mesh setup, data, fault tolerance, checkpointing.

CPU-scale by default (reduced configs); the same code path drives pod-scale
runs — the mesh/shardings come from the same rules the dry-run validates.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced_config
from repro.data.pipeline import SyntheticLM, Prefetcher
from repro.launch.mesh import make_host_mesh
from repro.parallel import sharding as S
from repro.train import checkpoint as CKPT
from repro.train.fault import PreemptionGuard, StragglerWatchdog
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import make_train_step
from repro.train.train_state import TrainState, init_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log", default=None, help="metrics JSONL path")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(args.model_parallel)
    opt = AdamW(schedule=warmup_cosine(args.lr, max(2, args.steps // 10),
                                       args.steps))
    step_fn = make_train_step(cfg, opt, accum_steps=args.accum)

    state = init_state(jax.random.key(args.seed), cfg, opt)
    pshard = S.param_shardings(cfg, state.params, mesh)
    state_shard = TrainState(step=NamedSharding(mesh, P()), params=pshard,
                             opt_state=type(state.opt_state)(
                                 count=NamedSharding(mesh, P()),
                                 mu=pshard, nu=pshard))
    state = jax.device_put(state, state_shard)

    start = 0
    if args.resume and args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        state, extra, start = CKPT.restore(args.ckpt_dir, state,
                                           shardings=state_shard)
        print(f"resumed from step {start}")

    jstep = jax.jit(step_fn, donate_argnums=(0,))
    data = Prefetcher(iter(SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                                       seed=args.seed)))
    guard = PreemptionGuard()
    watchdog = StragglerWatchdog(
        on_straggler=lambda s, dt, med: print(
            f"[straggler] step {s}: {dt:.2f}s vs median {med:.2f}s"))
    saver = CKPT.AsyncSaver()
    logf = open(args.log, "a") if args.log else None

    t_start = time.time()
    for step in range(start, args.steps):
        watchdog.step_start()
        batch = next(data)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = jstep(state, batch)
        dt = watchdog.step_end(step)
        m = {k: float(v) for k, v in metrics.items()}
        m |= {"step": step + 1, "wall_s": round(dt, 4)}
        print(f"step {step+1:5d} loss={m['loss']:.4f} "
              f"gnorm={m['grad_norm']:.3f} {dt*1e3:.0f}ms", flush=True)
        if logf:
            logf.write(json.dumps(m) + "\n")
            logf.flush()
        want_ckpt = args.ckpt_dir and ((step + 1) % args.ckpt_every == 0
                                       or step + 1 == args.steps)
        if guard.should_stop:   # graceful preemption: checkpoint + exit
            if args.ckpt_dir:
                saver.wait()
                CKPT.save(args.ckpt_dir, step + 1, state)
            print(f"preempted at step {step+1}; checkpoint written")
            break
        if want_ckpt:
            saver.save_async(args.ckpt_dir, step + 1, state)
    saver.wait()
    data.close()
    if logf:
        logf.close()
    n = args.steps - start
    print(f"done: {n} steps in {time.time()-t_start:.1f}s; "
          f"{len(watchdog.events)} straggler events")
    return state


if __name__ == "__main__":
    main()
