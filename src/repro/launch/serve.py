"""Serving driver: batched generation with the jitted decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
        --batch 4 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = M.init_params(jax.random.key(args.seed), cfg)
    cache_len = args.cache_len or (args.prompt_len + args.max_new)
    engine = ServeEngine(cfg, params, args.batch, cache_len)

    rng = jax.random.key(args.seed + 1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size, dtype=jnp.int32)
    frontend = None
    if cfg.family == "vlm" or cfg.is_encdec:
        frontend = jax.random.normal(
            rng, (args.batch, cfg.frontend_tokens, cfg.d_model))

    t0 = time.time()
    out = engine.generate(prompts, args.max_new, frontend=frontend)
    dt = time.time() - t0
    toks = out.shape[0] * out.shape[1]
    print(f"generated {out.shape} in {dt:.2f}s = {toks/dt:.1f} tok/s "
          f"(incl. prefill+compile)")
    print("sample:", out[0, :16].tolist())
    return out


if __name__ == "__main__":
    main()
