"""Request-stream serving driver: Poisson arrivals through the
continuous-batching engine (scheduler + slot table + chunked decode).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
        --batch 4 --requests 16 --rate 8 --prompt-lens 8,16,32 --max-new 32

`--rate` is the mean arrival rate in requests/s (exponential inter-arrival
times); 0 queues everything up-front. Prompt lengths cycle through the
`--prompt-lens` set (each distinct length costs one prefill retrace).
Frontend archs (vlm / enc-dec) fall back to static-batch `generate` — the
continuous engine is text-only for now — with the same honest accounting:
tok/s counts real generated tokens (nothing past EOS), and prefill vs
decode wall time are reported separately.

`--prefix-mix p` prepends one fixed `--prefix-len`-token system prompt to
a fraction p of the requests: with the prefix cache on (REPRO_PREFIX_CACHE,
default 1, paged layout) those requests prefill the shared span once and
later admissions map the cached pages with refcount bumps — the summary's
`prefix_hits` / `prefix_tokens_saved` / `pages_cached` fields and the lower
`pages_peak_in_use` / `prefill_s` quantify the win, and TTFT stays honest
(it times the suffix prefill a hit actually pays, not the full prefill it
skipped). The same seed with `REPRO_PREFIX_CACHE=0` serves the identical
stream without sharing — outputs are pinned token-identical.

`--tier-mix p` marks each request "bulk" with probability p (seeded):
bulk requests may decode on the approximate-normalization datapath (the
coarse-LZA design of arxiv 2408.11997 — see core/chained_fma.approx_*)
whenever a whole chunk is bulk; premium requests always get the exact
round-once datapath. With a mix, the driver also runs the engine's
divergence probe (teacher-forced exact-vs-approx logits on one prompt;
max-ulp is bounded by the dropped guard bits — see DESIGN.md §6) and the
per-tier modeled energy summary (core/energy.py tier_energy_summary).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import SlotScheduler


def build_requests(sched: SlotScheduler, cfg, n: int, rate: float,
                   prompt_lens: list[int], max_new: int, seed: int,
                   tier_mix: float = 0.0, prefix_mix: float = 0.0,
                   prefix_len: int = 32):
    """Queue `n` synthetic requests. `prefix_mix p` prepends one fixed
    `prefix_len`-token system prompt (drawn once per run) to a fraction p
    of the requests — the shared-system-prompt fleet the prefix cache
    multiplies: under REPRO_PREFIX_CACHE=1 those prompts prefill the shared
    span once and later admissions map the cached pages. The request
    stream is a pure function of `seed`, so A/B runs with the cache on and
    off serve the identical workload."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, cfg.vocab_size, prefix_len)
    t = 0.0
    for i in range(n):
        if rate > 0:
            t += float(rng.exponential(1.0 / rate))
        plen = prompt_lens[i % len(prompt_lens)]
        prompt = rng.integers(0, cfg.vocab_size, plen)
        if rng.random() < prefix_mix:
            prompt = np.concatenate([system, prompt])
        tier = "bulk" if rng.random() < tier_mix else "premium"
        sched.submit(prompt, max_new_tokens=max_new, arrival_time=t,
                     tier=tier)


def spec_warmup_train(cfg, params, steps: int, seed: int):
    """Seed-pure warm-up training for the speculative-decoding demo.

    Random init weights are random rotations layer to layer — the early-
    exit draft's argmax agrees with the full model's ~10% of the time, so
    speculation can only lose. Real deployments speculate on *trained*
    models; this stands in for that with a few hundred AdamW steps on an
    order-1 Markov corpus (each token has a dominant successor drawn once
    from `seed`, taken with p=0.9), which is learnable by the early layers
    alone — exactly the regime where a shallow draft agrees with the full
    stack. Pure function of (cfg, seed): the REPRO_SPEC_DECODE=1|0 A/B
    trains identical weights on both sides.
    """
    import dataclasses

    from repro.train.optimizer import AdamW, constant_lr
    from repro.train.step import make_train_step
    from repro.train.train_state import TrainState

    rng = np.random.default_rng(seed + 11)
    succ = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)

    def markov_batch(bsz=8, T=32):
        toks = np.empty((bsz, T + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=bsz)
        for t in range(T):
            toks[:, t + 1] = np.where(
                rng.random(bsz) < 0.9, succ[toks[:, t]],
                rng.integers(0, cfg.vocab_size, size=bsz))
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}

    opt = AdamW(constant_lr(3e-3), weight_decay=0.0)
    # remat trades compute for memory — pointless at warm-up scale
    step = jax.jit(make_train_step(dataclasses.replace(cfg, remat=False),
                                   opt))
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt_state=opt.init(params))
    metrics = {}
    for _ in range(steps):
        state, metrics = step(state, markov_batch())
    print(f"[spec-warmup] steps={steps} "
          f"final_loss={float(metrics.get('loss', float('nan'))):.3f}")
    return state.params


def preseed_decode_blocks(cfg, batch: int, page_size: int | None = None,
                          max_pages: int | None = None,
                          spec_k: int = 0):
    """Sweep decode-shape GEMV blocks before serving starts.

    The jitted decode step cannot sweep mid-trace (autotune.lookup falls
    back to the heuristic there), so winners must be in the cache before
    the first chunk compiles. Seeds the (N, K) pairs the decode step's
    projections actually look up — QKV (d→heads), out-proj (heads→d),
    FFN up/down, lm head — at M = batch (the decode GEMMs flatten
    (B, 1, D) to (B, D), so batch IS the GEMM M; other Ms would never be
    consulted). Epilogue-fused keys (e.g. the silu'd gate) fall back to
    these bare-GEMM entries (autotune.lookup's documented fallback).

    When the engine serves the paged KV layout (`page_size`/`max_pages`
    given), also sweeps the fused decode-attention grid shapes
    (pages_per_block, heads_per_block) for the exact workload the chunk fn
    will lower — same cannot-sweep-mid-trace constraint, same cache.

    With `spec_k > 0`, also pre-seeds the speculative verify forward's
    GEMM shapes at M = batch·(spec_k+1) (autotune.tune_spec_verify) — the
    batched verify is the one decode-path GEMM that doesn't run at
    M = batch."""
    from repro.kernels import autotune

    dtype = autotune.production_dtype()
    d, hd = cfg.d_model, cfg.hd
    shapes = {(cfg.num_heads * hd, d), (cfg.num_kv_heads * hd, d),
              (d, cfg.num_heads * hd), (cfg.padded_vocab, d)}
    ff = cfg.d_ff_dense or cfg.d_ff
    if ff:
        shapes |= {(ff, d), (d, ff)}
    for n, k in sorted(shapes):
        if spec_k:
            autotune.tune_spec_verify(n, k, batch, spec_k, dtype=dtype,
                                      reps=2)
        else:
            autotune.tune_decode(n, k, ms=(batch,), dtype=dtype, reps=2)
    if page_size and max_pages:
        kvh = cfg.num_kv_heads
        autotune.tune_decode_attn(batch, kvh, cfg.num_heads // kvh, hd,
                                  page_size, max_pages, reps=2)


def _itl_p50_ms(finished) -> float | None:
    """Median per-request inter-token latency (ms): decode wall after the
    first token / tokens after the first. The disagg acceptance metric —
    it must stay flat while decode stalls drop."""
    itls = [(r.t_done - r.t_first_token) / (r.n_generated - 1)
            for r in finished
            if r.t_first_token is not None and r.t_done is not None
            and r.n_generated >= 2]
    if not itls:
        return None
    return round(float(np.percentile(itls, 50)) * 1000, 3)


def _print_phases(summary) -> None:
    """Honest per-phase wall split (engine.serve accounting comment):
    prefill/decode busy walls are real measurements in both modes;
    decode_stall is the decode-blocking component — the whole admission
    prefill in unified mode, only the synced handoff in two-pool mode."""
    print(f"[phases] disagg={summary.get('disagg')} "
          f"prefill_busy={summary.get('prefill_busy_s')}s "
          f"decode_busy={summary.get('decode_busy_s')}s "
          f"handoff={summary.get('handoff_s')}s "
          f"decode_stall={summary.get('decode_stall_s')}s "
          f"itl_p50={summary.get('decode_itl_p50_ms')}ms "
          f"ready_p50={summary.get('ready_depth_p50')} "
          f"prefill_compiles={summary.get('prefill_compiles')}")


def _make_engine(args, cfg, params) -> ServeEngine:
    return ServeEngine(cfg, params, args.batch, args.cache_len,
                       eos_id=args.eos_id, sync_every=args.sync_every,
                       kv_layout=args.kv, page_size=args.page_size,
                       pool_pages=args.pool_pages,
                       max_seq_len=args.max_seq_len, spec_k=args.spec_k,
                       spec_draft_layers=args.spec_draft_layers or None,
                       disagg=args.disagg or None,
                       prefill_workers=args.prefill_workers,
                       bucket_prompts=args.bucket_prompts or None)


def serve_continuous(args, cfg, params, plens) -> dict:
    if args.autotune_decode:
        import os as _os
        paged = (args.kv or _os.environ.get("REPRO_KV", "paged")) == "paged"
        seq = args.max_seq_len or args.cache_len
        max_pages = -(-seq // args.page_size) if paged else None
        preseed_decode_blocks(cfg, args.batch,
                              page_size=args.page_size if paged else None,
                              max_pages=max_pages, spec_k=args.spec_k)
    engine = _make_engine(args, cfg, params)
    sched = SlotScheduler(args.batch, eos_id=args.eos_id)
    build_requests(sched, cfg, args.requests, args.rate, plens,
                   args.max_new, args.seed, tier_mix=args.tier_mix,
                   prefix_mix=args.prefix_mix, prefix_len=args.prefix_len)
    summary = engine.serve(sched, greedy=True)
    # digest of the full rid-ordered token streams: the spec-decode CI leg
    # pins REPRO_SPEC_DECODE=1|0 byte-identical through this one field
    # without dumping every token into the summary line
    import hashlib
    streams = ",".join(
        f"{r.rid}:{'-'.join(map(str, r.tokens))}"
        for r in sorted(sched.finished, key=lambda r: r.rid))
    summary["stream_digest"] = hashlib.sha1(streams.encode()).hexdigest()[:16]
    itl = _itl_p50_ms(sched.finished)
    if itl is not None:
        summary["decode_itl_p50_ms"] = itl
    _print_phases(summary)
    if engine.spec_decoding_on() and summary.get("spec_iters"):
        # honest accounting: decode_tok_s above already counts only
        # accepted tokens (rejected drafts never reach a Request); the
        # draft/verify split is measured standalone at serving shapes
        # (spec_timing_probe — the two phases share one jitted scan in
        # serve(), so they cannot be timed in situ) and scaled by the
        # iteration count actually run
        split = engine.spec_timing_probe()
        iters = summary["spec_iters"]
        summary["spec_draft_s"] = round(split["draft_s"] * iters, 4)
        summary["spec_verify_s"] = round(split["verify_s"] * iters, 4)
        print(f"[spec] k={engine.spec_k} "
              f"draft_layers={engine.spec_draft_layers}/"
              f"{cfg.num_layers // cfg.stack_period} "
              f"accept_rate={summary.get('spec_accept_rate', 0.0)} "
              f"accepted={summary.get('spec_accepted', 0)}/"
              f"drafted={summary.get('spec_drafted', 0)} "
              f"draft_s~{summary['spec_draft_s']} "
              f"verify_s~{summary['spec_verify_s']}")
    for r in sorted(sched.finished, key=lambda r: r.rid):
        # rejected requests never started: no TTFT / rate to report
        ttft = float("nan") if r.ttft is None else r.ttft
        print(f"req {r.rid:3d} slot {r.slot} {r.tier:7s} "
              f"prompt {r.prompt_len:4d} "
              f"gen {r.n_generated:4d} ({r.finish_reason or 'n/a':8s}) "
              f"ttft {ttft:.3f}s "
              f"decode {r.decode_tok_s or float('nan'):.1f} tok/s")
    if args.tier_mix > 0:
        from repro.core.energy import tier_energy_summary

        energy = tier_energy_summary(sched.tier_mode_tokens,
                                     engine.macs_per_token())
        summary |= {f"energy_{k}": v for k, v in energy.items()}
        rng = np.random.default_rng(args.seed + 7)
        probe = engine.divergence_probe(
            rng.integers(0, cfg.vocab_size, plens[0]),
            steps=min(16, args.max_new))
        print(f"[divergence] steps={probe['steps']} "
              f"max_ulp={probe['max_ulp']} kl_mean={probe['kl_mean']:.3e} "
              f"max_abs_diff={probe['max_abs_diff']:.3e}")
        summary |= {f"divergence_{k}": v for k, v in probe.items()}
    return summary


def serve_replicas(args, cfg, params, plens) -> dict:
    """`--decode-replicas N`: N data-parallel engine replicas behind one
    shared arrival stream (DESIGN.md §10). The stream is built once, then
    each request is routed up-front in arrival order by pick-least-loaded
    (scheduler.ReplicaRouter — a pure function of the submitted stream, so
    the aggregate digest is reproducible and replica-count-independent
    routing ties go to the lowest index). Single-host emulation: replicas
    share `params` and serve SEQUENTIALLY on this process's devices, so
    per-replica walls and ITL are real; the aggregate reports the modeled
    parallel wall = max(replica walls) next to the serial wall actually
    paid. Requests keep their global rids across replicas, so the
    aggregate `stream_digest` is comparable with a 1-replica run of the
    same stream."""
    import hashlib

    from repro.serve.scheduler import ReplicaRouter

    n = args.decode_replicas
    master = SlotScheduler(args.batch, eos_id=args.eos_id)
    build_requests(master, cfg, args.requests, args.rate, plens,
                   args.max_new, args.seed, tier_mix=args.tier_mix,
                   prefix_mix=args.prefix_mix, prefix_len=args.prefix_len)
    router = ReplicaRouter(n)
    scheds = [SlotScheduler(args.batch, eos_id=args.eos_id)
              for _ in range(n)]
    for req in master.pending:     # already arrival-sorted
        i = router.route(req.prompt_len, req.max_new_tokens)
        r2 = scheds[i].submit(req.prompt, req.max_new_tokens,
                              arrival_time=req.arrival_time, tier=req.tier)
        r2.rid = req.rid           # global rid: aggregate digest key

    summaries = []
    finished = []
    for i, sched in enumerate(scheds):
        engine = _make_engine(args, cfg, params)
        s = engine.serve(sched, greedy=True)
        s["decode_itl_p50_ms"] = _itl_p50_ms(sched.finished)
        summaries.append(s)
        finished.extend(sched.finished)
        print(f"[replica {i}] requests={s['requests']} "
              f"wall_s={s['wall_s']} decode_tok_s={s['decode_tok_s']} "
              f"itl_p50={s['decode_itl_p50_ms']}ms "
              f"pages_leaked={s.get('pages_leaked')} "
              f"decode_stall={s.get('decode_stall_s')}s")

    def total(key):
        return round(sum(s.get(key) or 0 for s in summaries), 4)

    streams = ",".join(
        f"{r.rid}:{'-'.join(map(str, r.tokens))}"
        for r in sorted(finished, key=lambda r: r.rid))
    summary = {
        "replicas": n,
        "requests": sum(s["requests"] for s in summaries),
        "generated_tokens": sum(s["generated_tokens"] for s in summaries),
        "rejected": sum(s.get("rejected", 0) for s in summaries),
        "pages_leaked": total("pages_leaked"),
        "prefill_busy_s": total("prefill_busy_s"),
        "decode_busy_s": total("decode_busy_s"),
        "handoff_s": total("handoff_s"),
        "decode_stall_s": total("decode_stall_s"),
        "prefill_compiles": sum(s.get("prefill_compiles", 0)
                                for s in summaries),
        # serial = what this single-host emulation paid; parallel = the
        # deployment model (replicas run concurrently, wall = slowest)
        "wall_s_serial": total("wall_s"),
        "wall_s_parallel": round(max(s["wall_s"] for s in summaries), 4),
        "disagg": summaries[0].get("disagg"),
        "ready_depth_p50": summaries[0].get("ready_depth_p50"),
        "stream_digest":
            hashlib.sha1(streams.encode()).hexdigest()[:16],
    }
    itl = _itl_p50_ms(finished)
    if itl is not None:
        summary["decode_itl_p50_ms"] = itl
    _print_phases(summary)
    return summary


def serve_static(args, cfg, params, plens) -> dict:
    """Static-batch fallback (frontend archs): `--requests` prompts served
    in waves of `--batch` (arrivals/`--rate` don't apply — each wave blocks
    on its slowest member; that gap is exactly the continuous engine's
    point), same honest accounting as the continuous path. Prompt lengths
    cycle per *wave* (a wave's batch prefill is rectangular)."""
    engine = ServeEngine(cfg, params, args.batch, args.cache_len,
                         eos_id=args.eos_id, sync_every=args.sync_every)
    served = n_real = 0
    prefill_s = decode_s = 0.0
    waves = max(1, -(-args.requests // args.batch))
    for w in range(waves):
        plen = plens[w % len(plens)]
        rng = jax.random.key(args.seed + 1 + w)
        prompts = jax.random.randint(rng, (args.batch, plen), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        frontend = None
        if cfg.family == "vlm" or cfg.is_encdec:
            frontend = jax.random.normal(
                rng, (args.batch, cfg.frontend_tokens, cfg.d_model))
        out = np.asarray(engine.generate(prompts, args.max_new,
                                         frontend=frontend))
        # only this wave's real requests count (the last wave may be ragged)
        n_rows = min(args.batch, args.requests - served)
        # real generated tokens: through the first EOS per row, no further
        for row in out[:n_rows]:
            eos = np.nonzero(row == args.eos_id)[0]
            n_real += int(eos[0]) + 1 if eos.size else row.shape[0]
        served += n_rows
        prefill_s += engine.last_stats["prefill_s"]
        decode_s += engine.last_stats["decode_s"]
    return {"requests": served, "generated_tokens": n_real,
            "waves": waves,
            "prefill_s": round(prefill_s, 4),
            "decode_s": round(decode_s, 4),
            "decode_tok_s": round(max(n_real - served, 0) / decode_s, 2)
            if decode_s > 0 else 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (continuous) / wave size (static)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    ap.add_argument("--prompt-lens", default="8,16,32",
                    help="comma-set of prompt lengths, cycled per request")
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=None)
    ap.add_argument("--kv", default=None, choices=(None, "ring", "paged"),
                    help="KV layout (default: $REPRO_KV or 'paged'): "
                         "'paged' pools pages across slots with per-slot "
                         "block tables; 'ring' is the per-slot dense "
                         "fallback (DESIGN.md §5)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged layout)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="total pages in the pool (default: dense-ring-"
                         "equivalent batch*cache_len tokens + trash page)")
    ap.add_argument("--max-seq-len", type=int, default=None,
                    help="per-request token cap = block-table width "
                         "(default: cache-len) — raise it to admit one "
                         "long request without growing every slot")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps per host sync / scheduler tick")
    ap.add_argument("--prefix-mix", type=float, default=0.0,
                    help="fraction of requests sharing one fixed system "
                         "prompt — the prefix-cache workload (REPRO_PREFIX_"
                         "CACHE=1|0 A/Bs sharing on the same stream; TTFT "
                         "stays honest, timing only the suffix prefill a "
                         "cache hit actually pays)")
    ap.add_argument("--prefix-len", type=int, default=32,
                    help="shared system-prompt length for --prefix-mix")
    ap.add_argument("--tier-mix", type=float, default=0.0,
                    help="fraction of requests submitted as the 'bulk' "
                         "quality tier (approximate-normalization decode "
                         "when a whole chunk is bulk); 0 = all premium")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative draft length (0 = off): draft "
                         "spec-k tokens per slot with the early-exit "
                         "forward, verify them in one batched M=spec-k+1 "
                         "forward, keep the longest agreeing prefix "
                         "(DESIGN.md §9). Greedy output is token-identical "
                         "to spec-k 0; REPRO_SPEC_DECODE=0 kill-switches")
    ap.add_argument("--spec-draft-layers", type=int, default=0,
                    help="superblocks the draft forward runs "
                         "(0 = half the stack)")
    ap.add_argument("--spec-warmup", type=int, default=0,
                    help="seed-pure AdamW warm-up steps on a synthetic "
                         "Markov corpus before serving — stands in for "
                         "trained weights so the draft's acceptance rate "
                         "is meaningful (random init accepts ~10%)")
    ap.add_argument("--layers-per-period", type=int, default=1,
                    help="depth multiplier for --reduced configs (the "
                         "early-exit draft needs >= 2 superblocks)")
    ap.add_argument("--width", type=int, default=1,
                    help="width multiplier for --reduced configs "
                         "(d_model/d_ff × width) — width >= 4 leaves the "
                         "dispatch-bound floor so depth-proportional "
                         "speedups (--spec-k) are measurable")
    ap.add_argument("--disagg", action="store_true",
                    help="two-pool disaggregated serving (DESIGN.md §10): "
                         "prefill workers stage finished prompts' KV pages "
                         "and a ready queue feeds decode admissions, so "
                         "decode chunks never block on a prefill. Paged "
                         "layout only; token-identical to unified "
                         "(REPRO_DISAGG=1 is the env equivalent)")
    ap.add_argument("--prefill-workers", type=int, default=1,
                    help="prefill-pool width under --disagg: prompts staged "
                         "per scheduler tick before decode resumes")
    ap.add_argument("--decode-replicas", type=int, default=1,
                    help="N data-parallel engine replicas behind the shared "
                         "arrival queue, routed pick-least-loaded "
                         "(scheduler.ReplicaRouter); served sequentially "
                         "on this host, parallel wall modeled as "
                         "max(replica walls)")
    ap.add_argument("--bucket-prompts", action="store_true",
                    help="prompt-length bucketing for attention-only archs: "
                         "pad prefill to ~1.5x-spaced buckets to cut jit "
                         "retraces (summary: prefill_compiles); "
                         "token-identical (REPRO_PREFILL_BUCKET=1 is the "
                         "env equivalent)")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="EOS token id (-1: never fires on synthetic vocab)")
    ap.add_argument("--autotune-decode", action="store_true",
                    help="pre-seed decode-shape GEMV blocks (autotune."
                         "tune_decode) before the first chunk compiles")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (reduced_config(args.arch,
                          layers_per_period=args.layers_per_period,
                          width=args.width)
           if args.reduced else get_config(args.arch))
    params = M.init_params(jax.random.key(args.seed), cfg)
    if args.spec_warmup > 0:
        params = spec_warmup_train(cfg, params, args.spec_warmup, args.seed)
    plens = [int(x) for x in args.prompt_lens.split(",")]
    # prefix-mix prompts grow by the shared system prompt; size the default
    # per-request capacity to still fit them
    extra = args.prefix_len if args.prefix_mix > 0 else 0
    args.cache_len = args.cache_len or (max(plens) + extra + args.max_new)

    if cfg.family == "vlm" or cfg.is_encdec:
        summary = serve_static(args, cfg, params, plens)
        mode = "static"
    elif args.decode_replicas > 1:
        summary = serve_replicas(args, cfg, params, plens)
        mode = f"replicas x{args.decode_replicas}"
    else:
        summary = serve_continuous(args, cfg, params, plens)
        mode = "continuous"
    print(f"[{mode}] " + " ".join(f"{k}={v}" for k, v in summary.items()))
    return summary


if __name__ == "__main__":
    main()
