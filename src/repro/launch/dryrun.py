import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces (artifacts/dryrun/<arch>__<shape>__<mesh>.json):
  * compile success + wall time,
  * memory_analysis (per-device argument/output/temp/peak bytes — proves fit),
  * cost_analysis   (HLO FLOPs / bytes accessed — roofline numerator),
  * per-class collective payload bytes parsed from the optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — the collective roofline term.

Meshes: `pod` = (16, 16) single pod (roofline baseline),
        `multipod` = (2, 16, 16) 512 chips (proves the pod axis shards).

Usage:
    python -m repro.launch.dryrun --all [--resume]
    python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k --mesh pod
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import REGISTRY, get_config
from repro.models.config import SHAPES, SHAPES_BY_NAME
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SP
from repro.core import precision

ART_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "artifacts", "dryrun"))

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-class payload bytes: max tensor in each collective op line
    (≈ ring payload per device for gather/reduce family)."""
    out: dict[str, float] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[-1][:40]:
            continue
        op = m.group(1)
        sizes = [_tensor_bytes(d, s) for d, s in _SHAPE_RE.findall(line)]
        if not sizes:
            continue
        out[op] = out.get(op, 0) + max(sizes)
        count[op] = count.get(op, 0) + 1
    out["total"] = sum(v for k, v in out.items())
    out["counts"] = count
    return out


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {k: int(getattr(ma, k)) for k in keys if hasattr(ma, k)}
    if "argument_size_in_bytes" in d and "temp_size_in_bytes" in d:
        d["peak_estimate_bytes"] = (d["argument_size_in_bytes"]
                                    + d["output_size_in_bytes"]
                                    + d["temp_size_in_bytes"])
    return d


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             keep_text: bool = False, accum: int | None = None,
             kv: str = "ring", disagg: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = SP.cell_is_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "family": cfg.family, "params": cfg.param_count()}
    if kv != "ring":
        rec["kv_layout"] = kv
    if not ok:
        rec |= {"status": "skipped", "reason": why}
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    try:
        from repro.parallel.sharding import set_active_mesh
        set_active_mesh(mesh)   # activation constraints inside model code
        step_fn, args, in_sh, out_sh = SP.input_specs(cfg, shape, mesh,
                                                      accum=accum,
                                                      kv_layout=kv)
        # donation mirrors production: train donates the state, serving
        # donates the KV/SSM cache (in-place update on device)
        donate = (0,) if shape.kind == "train" else (2,)
        with mesh:
            jitted = jax.jit(step_fn, in_shardings=in_sh,
                             out_shardings=out_sh, donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        cost = dict(compiled.cost_analysis() or {})
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
        text = compiled.as_text()
        # trip-count-aware analysis: XLA's cost_analysis counts while (scan)
        # bodies once; HLOCost multiplies by parsed trip counts (see
        # launch/hlo_cost.py) — this is the roofline numerator.
        from repro.launch.hlo_cost import HLOCost
        hc = HLOCost(text).summary()
        rec |= {
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_devices": int(mesh.devices.size),
            "memory": memory_dict(compiled),
            "xla_cost_flops": cost.get("flops", 0.0),
            "xla_cost_bytes": cost.get("bytes accessed", 0.0),
            "flops": hc["flops"],
            "bytes_accessed": hc["bytes"],
            "collectives": {"total": hc["collective_bytes"],
                            **hc["collectives_by_class"],
                            "legacy_line_parse": collective_bytes(text)},
            "hlo_chars": len(text),
        }
        if keep_text:
            rec["hlo_text"] = text
        if (disagg and shape.kind == "decode" and kv == "paged"
                and cfg.family != "ssm"):
            # two-pool lowering (DESIGN.md §10): additionally compile the
            # KV-page handoff program — the scatter+bind splice the
            # disaggregated engine pays per prefill completion — on the
            # same mesh and pool shardings, so the artifact answers "what
            # does one handoff cost here" next to the decode step itself
            t_h = time.time()
            h_fn, h_args, h_in, h_out = SP.handoff_specs(cfg, shape, mesh)
            with mesh:
                h_jit = jax.jit(h_fn, in_shardings=h_in,
                                out_shardings=h_out, donate_argnums=(0,))
                h_comp = h_jit.lower(*h_args).compile()
            h_text = h_comp.as_text()
            from repro.launch.hlo_cost import HLOCost as _HC
            hh = _HC(h_text).summary()
            rec["handoff"] = {
                "compile_s": round(time.time() - t_h, 2),
                "memory": memory_dict(h_comp),
                "flops": hh["flops"],
                "bytes_accessed": hh["bytes"],
                "collectives": {"total": hh["collective_bytes"],
                                **hh["collectives_by_class"]},
            }
    except Exception as e:  # a failing cell is a bug: record it loudly
        rec |= {"status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:]}
    return rec


def cell_path(arch: str, shape: str, mesh: str, kv: str = "ring",
              disagg: bool = False) -> str:
    """Non-default KV layouts get their own artifact namespace so a paged
    sweep never collides with (or --resume-skips into) the ring records;
    disagg sweeps (decode cell + handoff program) likewise."""
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = "" if kv == "ring" else f"__kv-{kv}"
    if disagg:
        suffix += "__disagg"
    return os.path.join(ART_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=(None, "pod", "multipod"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already exists")
    ap.add_argument("--accum", type=int, default=None,
                    help="override gradient-accumulation microsteps")
    ap.add_argument("--kv", default="ring", choices=("ring", "paged"),
                    help="KV layout for decode cells: per-slot dense rings "
                         "or the paged pool + block table (DESIGN.md §5)")
    ap.add_argument("--disagg", action="store_true",
                    help="two-pool lowering: also compile the KV-page "
                         "handoff program for paged decode cells "
                         "(DESIGN.md §10); records a 'handoff' section")
    args = ap.parse_args()

    # lower the TPU-true program (bf16 containers), not the CPU-exec variant
    precision.EXACT_CPU_CONTAINERS = False

    archs = [args.arch] if args.arch else list(REGISTRY)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPES]
    meshes = [args.mesh] if args.mesh else ["pod", "multipod"]
    n_bad = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(arch, shape, mesh_kind, kv=args.kv,
                                 disagg=args.disagg)
                if args.resume and os.path.exists(path):
                    with open(path) as f:
                        old = json.load(f)
                    if old.get("status") in ("ok", "skipped"):
                        continue
                rec = run_cell(arch, shape, mesh_kind, accum=args.accum,
                               kv=args.kv, disagg=args.disagg)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    mem = rec["memory"].get("peak_estimate_bytes", 0) / 2**30
                    extra = (f"compile={rec['compile_s']:.1f}s "
                             f"peak/dev={mem:.2f}GiB "
                             f"coll={rec['collectives']['total']/2**20:.1f}MiB")
                elif status == "error":
                    n_bad += 1
                    extra = rec["error"][:120]
                print(f"[{status:7s}] {arch:28s} {shape:12s} {mesh_kind:9s} "
                      f"{extra}", flush=True)
    raise SystemExit(1 if n_bad else 0)


if __name__ == "__main__":
    main()
