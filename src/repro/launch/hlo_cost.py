"""Trip-count-aware cost analysis of optimized HLO text.

XLA's `compiled.cost_analysis()` visits every computation once — `lax.scan`
bodies (layer stacks, grad-accum loops) are counted a single time, under-
reporting FLOPs/bytes by the trip count (observed up to ~320× on the 40-layer
× 8-µbatch cells). This analyzer walks the HLO text, resolves computation
references (`calls=`, `body=`/`condition=`, `to_apply=`), multiplies while
bodies by their parsed trip counts, and accumulates:

  * flops  — 2·|out|·K for every `dot` (contracted sizes from the symbol
    table + `lhs_contracting_dims`); convolutions likewise.
  * bytes  — per *materialized* op: output + operand bytes. Fusion calls
    count only their operands/output (internal temporaries stay in
    registers/VMEM — that is what fusion means); aliasing ops (bitcast,
    tuple, get-tuple-element, parameter) are free; collectives are tracked
    separately (they are the collective roofline term, not HBM traffic).
  * collective payload bytes per class (max-operand proxy ≈ ring payload).

Trip counts: scan lowers to `while(cond: iv < constant N)`; we take the max
integer constant in the condition computation. This is exact for jax scans
and a safe upper bound otherwise.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_CALLREF_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_FREE_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
             "bitcast(", "after-all(", "partition-id(", "replica-id(")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
# Byte accounting assumes TPU-grade fusion: only ops that materialize HBM
# traffic are counted (CPU HLO leaves elementwise chains unfused — counting
# every op line overstates TPU traffic ~30×). Elementwise/convert/broadcast
# are assumed fused into their consumers.
_MATERIALIZING = (" dot(", " gather(", " scatter(", " dynamic-slice(",
                  " dynamic-update-slice(", " copy(", " reduce(", " sort(",
                  " concatenate(", " pad(", " slice(", " reverse(",
                  " transpose(", " rng", " cholesky(", " fft(",
                  " convolution(", " select-and-scatter(", " reduce-window(")


def _dims(dims: str):
    return [int(d) for d in dims.split(",") if d]


def _tensor_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n * _DTYPE_BYTES.get(dtype, 4)


def _first_shapes_bytes(text: str) -> int:
    return sum(_tensor_bytes(d, s) for d, s in _SHAPE_RE.findall(text))


class _Comp:
    def __init__(self, name):
        self.name = name
        self.lines: list[str] = []
        self.shapes: dict[str, tuple[str, str]] = {}   # %name -> (dtype, dims)


def _parse_computations(text: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
            continue
        if stripped == "}" or stripped.startswith("} "):
            cur = None
            continue
        d = _DEF_RE.match(line)
        if d:
            name, rhs = d.groups()
            sm = _SHAPE_RE.search(rhs)
            if sm:
                cur.shapes[name] = (sm.group(1), sm.group(2))
            cur.lines.append(line)
    return comps


class HLOCost:
    def __init__(self, text: str):
        self.comps = _parse_computations(text)
        self._memo: dict[str, tuple[float, float, dict]] = {}
        # entry = first computation marked ENTRY; fall back to the largest
        entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR.match(line.strip())
                if m:
                    entry = m.group(1)
                break
        self.entry = entry or max(self.comps, key=lambda c:
                                  len(self.comps[c].lines))
        self.flops, self.bytes, self.coll = self._cost(self.entry)

    # -- helpers ---------------------------------------------------------
    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if not comp:
            return 1
        consts = [int(x) for line in comp.lines
                  for x in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    def _dot_flops(self, comp: _Comp, line: str) -> float:
        d = _DEF_RE.match(line)
        if not d:
            return 0.0
        rhs = d.group(2)
        out = _SHAPE_RE.search(rhs)
        if not out:
            return 0.0
        out_elems = 1
        for x in _dims(out.group(2)):
            out_elems *= x
        opnds = _OPND_RE.findall(rhs.split("(", 1)[1])
        lhs = opnds[0] if opnds else None
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
        k = 1
        if lhs and lhs in comp.shapes and cm:
            ldims = _dims(comp.shapes[lhs][1])
            for ci in _dims(cm.group(1)):
                if ci < len(ldims):
                    k *= ldims[ci]
        return 2.0 * out_elems * k

    def _root_kind(self, comp_name: str) -> str:
        comp = self.comps.get(comp_name)
        if comp:
            for line in comp.lines:
                if line.strip().startswith("ROOT"):
                    return line
        return ""

    def _line_bytes(self, comp: _Comp, line: str) -> float:
        """HBM traffic of one materialized op.

        In-place/slice semantics: a dynamic-update-slice writes the update
        slice, not the whole (aliased) buffer — charging the full stacked
        scan buffer per trip overstates traffic ~30×. Slice-style reads
        (dynamic-slice/gather/slice) touch output-sized data, not the whole
        source. Reduce-style ops legitimately read their full operands.
        """
        d = _DEF_RE.match(line)
        if not d:
            return 0.0
        name, rhs = d.groups()
        kind = rhs
        for ref in _CALLREF_RE.findall(rhs):
            kind += " " + self._root_kind(ref)
        update_style = "dynamic-update-slice" in kind
        slice_style = any(k in kind for k in
                          (" dynamic-slice(", " gather(", " slice("))
        out_b = _tensor_bytes(*comp.shapes[name]) if name in comp.shapes else 0.0
        opnds = []
        paren = rhs.split("(", 1)
        if len(paren) > 1:
            for op in _OPND_RE.findall(paren[1]):
                if op in comp.shapes and not op.startswith(("fused_", "wide.")):
                    opnds.append(_tensor_bytes(*comp.shapes[op]))
        if update_style:
            small = [b for b in opnds if b < out_b]
            return 2.0 * (max(small) if small else 0.0)   # read+write the slice
        if slice_style:
            return out_b + sum(min(b, out_b) for b in opnds)
        return out_b + sum(opnds)

    # -- main recursion ---------------------------------------------------
    def _cost(self, name: str) -> tuple[float, float, dict]:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        if comp is None:
            return 0.0, 0.0, {}
        self._memo[name] = (0.0, 0.0, {})   # cycle guard
        flops = 0.0
        byts = 0.0
        coll: dict[str, float] = {}
        for line in comp.lines:
            rhs = line.split("=", 1)[-1]
            if any(op in rhs for op in _FREE_OPS):
                continue
            cm = re.search(r"\b(" + "|".join(_COLL_OPS) + r")(-start)?\(", rhs)
            if cm:
                payload = 0.0
                d = _DEF_RE.match(line)
                if d:
                    sizes = [_tensor_bytes(dt, dm)
                             for dt, dm in _SHAPE_RE.findall(d.group(2))]
                    payload = max(sizes) if sizes else 0.0
                op = cm.group(1)
                coll[op] = coll.get(op, 0.0) + payload
                continue
            if " dot(" in rhs or rhs.lstrip().startswith("dot("):
                flops += self._dot_flops(comp, line)
                byts += self._line_bytes(comp, line)
                continue
            if " while(" in rhs:
                trip = 1
                c = _COND_RE.search(rhs)
                if c:
                    trip = self._trip_count(c.group(1))
                refs = _CALLREF_RE.findall(rhs)
                for ref in refs:
                    f, b, cl = self._cost(ref)
                    flops += f * trip
                    byts += b * trip
                    for k, v in cl.items():
                        coll[k] = coll.get(k, 0.0) + v * trip
                continue
            refs = _CALLREF_RE.findall(rhs)
            if refs and ("fusion(" in rhs or "call(" in rhs
                         or "conditional(" in rhs):
                for ref in refs:
                    f, _, cl = self._cost(ref)   # fused bytes: call-site only
                    flops += f
                    for k, v in cl.items():
                        coll[k] = coll.get(k, 0.0) + v
                byts += self._line_bytes(comp, line)
                continue
            if refs:   # reduce/map/sort to_apply: tiny bodies, count bytes
                byts += self._line_bytes(comp, line)
                continue
            if any(op in rhs for op in _MATERIALIZING):
                byts += self._line_bytes(comp, line)
            # plain elementwise / convert / broadcast: assumed fused (free)
        result = (flops, byts, coll)
        self._memo[name] = result
        return result

    def summary(self) -> dict:
        total_coll = sum(self.coll.values())
        return {"flops": self.flops, "bytes": self.bytes,
                "collective_bytes": total_coll,
                "collectives_by_class": dict(self.coll)}
