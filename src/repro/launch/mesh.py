"""Production mesh definitions (functions — importing never touches jax
device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Dev mesh over whatever devices exist (CPU smoke / small runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))
