"""Gradient compression for cross-replica reduction + error feedback.

At 1000+ nodes the gradient all-reduce over (pod, data) dominates step time
for FSDP-light archs; compressing the reduction payload trades precision for
ICI bandwidth. Two codecs:

  * ``bf16``  — round gradients to bf16 before the reduce (2× payload cut,
    the paper's own reduced-precision philosophy applied to the collective).
  * ``int8``  — per-leaf symmetric int8 quantization with **error feedback**
    (residual carried in the optimizer state; Karimireddy et al. 2019) —
    4× payload cut, unbiased in the long run.

`compressed_psum` is the shard_map building block; `make_error_feedback`
wires the residual into the train step. Validated in tests/test_compression.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, codec: str = "int8"):
    """grads → (payload, residual). residual = what the codec dropped."""
    flat, treedef = jax.tree.flatten(grads)
    if codec == "bf16":
        payload = [g.astype(jnp.bfloat16) for g in flat]
        resid = [g - p.astype(jnp.float32) for g, p in zip(flat, payload)]
    elif codec == "int8":
        payload = [quantize_int8(g) for g in flat]
        resid = [g - dequantize_int8(*p) for g, p in zip(flat, payload)]
    else:
        raise ValueError(f"unknown codec {codec!r}")
    return treedef.unflatten(payload), treedef.unflatten(resid)


def decompress_tree(payload, codec: str = "int8"):
    if codec == "bf16":
        return jax.tree.map(lambda p: p.astype(jnp.float32), payload)
    if codec == "int8":
        flat, treedef = jax.tree.flatten(
            payload, is_leaf=lambda x: isinstance(x, tuple)
            and len(x) == 2 and isinstance(x[0], jax.Array))
        return treedef.unflatten([dequantize_int8(*p) for p in flat])
    raise ValueError(f"unknown codec {codec!r}")


def compressed_psum(grads, axis_name: str, codec: str = "int8",
                    residual=None):
    """Inside shard_map: quantize → psum → dequantize, with error feedback.

    residual (same tree as grads, or None) is added before quantization and
    the new residual (quantization error) is returned for the next step.
    """
    if residual is not None:
        grads = jax.tree.map(jnp.add, grads, residual)
    if codec == "none":
        return jax.lax.psum(grads, axis_name), jax.tree.map(
            jnp.zeros_like, grads)
    if codec == "bf16":
        payload = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        new_resid = jax.tree.map(lambda g, p: g - p.astype(jnp.float32),
                                 grads, payload)
        summed = jax.lax.psum(payload, axis_name)
        return jax.tree.map(lambda s: s.astype(jnp.float32), summed), new_resid
    if codec == "int8":
        def leaf(g):
            # all shards must quantize on the SAME grid before the integer
            # reduction: agree on the max |g| scale first (one tiny pmax),
            # then psum int8 payloads in int32 (hardware-friendly ring).
            scale = jax.lax.pmax(
                jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0, axis_name)
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            s = jax.lax.psum(q.astype(jnp.int32), axis_name)
            return s.astype(jnp.float32) * scale, g - dequantize_int8(q, scale)
        pairs = jax.tree.map(leaf, grads)
        summed = jax.tree.map(lambda p: p[0], pairs,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_resid = jax.tree.map(lambda p: p[1], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return summed, new_resid
    raise ValueError(f"unknown codec {codec!r}")
