"""Sharding rules: parameter/activation PartitionSpecs for the production mesh.

Axes: `model` = TP/EP (attention heads, FFN width, experts, vocab);
`data` (+ `pod` when present) = DP, and additionally FSDP for archs flagged
`fsdp=True` (llama4-maverick: 400 B params must shard over *all* axes).
Stacked superblock leaves carry a leading scan dimension → specs are
prepended with None.

The rules are path-based over the param pytree, so new layer types only need
a new rule entry.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --------------------------------------------------------------------------
# Activation sharding constraints (used *inside* model code)
# --------------------------------------------------------------------------
# Model code runs both under the production mesh (dry-run, launchers) and
# meshless (CPU unit tests). Launchers register the active mesh; `constrain`
# becomes a no-op when none is set, and silently replicates any dim the mesh
# axis doesn't divide (same rule as parameter sharding).

_ACTIVE_MESH: Mesh | None = None


def set_active_mesh(mesh: Mesh | None):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def active_mesh() -> Mesh | None:
    return _ACTIVE_MESH


def axis_count(name: str) -> int:
    if _ACTIVE_MESH is None or name not in _ACTIVE_MESH.axis_names:
        return 1
    return dict(zip(_ACTIVE_MESH.axis_names,
                    _ACTIVE_MESH.devices.shape))[name]


def constrain(x, *axes):
    """with_sharding_constraint against the active mesh (no-op if none).

    `axes` entries: None, axis name, tuple of names, or "batch" (expands to
    the DP axes of the active mesh)."""
    if _ACTIVE_MESH is None:
        return x
    sizes = dict(zip(_ACTIVE_MESH.axis_names, _ACTIVE_MESH.devices.shape))

    def expand(a):
        if a == "batch":
            return batch_axes(_ACTIVE_MESH)
        return a

    def nsize(a):
        if a is None:
            return 1
        names = a if isinstance(a, tuple) else (a,)
        n = 1
        for x_ in names:
            n *= sizes[x_]
        return n

    axes = tuple(expand(a) for a in axes)
    axes = axes + (None,) * (x.ndim - len(axes))
    spec = P(*(a if d % nsize(a) == 0 else None
               for a, d in zip(axes, x.shape)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_ACTIVE_MESH, spec))


def _param_rule(path: str, ndim: int, cfg: ArchConfig, fsdp,
                model_size: int) -> P:
    """PartitionSpec for one (unstacked) parameter leaf."""
    f = fsdp if cfg.fsdp else None
    ep = cfg.num_experts > 0 and cfg.num_experts % model_size == 0
    # modality-agnostic rules, most-specific first
    if "embed" in path:
        return P("model", f)
    if "lm_head" in path:
        return P(f, "model")
    if any(k in path for k in ("wq", "wk", "wv", "wg", "wu", "w1")):
        if ndim == 3:                       # stacked experts (E, D, F)
            # EP when expert count divides the TP axis, else TP inside expert
            return P("model", f, None) if ep else P(None, f, "model")
        return P(f, "model")
    if any(k in path for k in ("wo", "wd", "w2")):
        if ndim == 3:                       # experts (E, F, D)
            return P("model", None, f) if ep else P(None, "model", f)
        return P("model", f)
    if "router" in path:
        return P(f, None)
    if any(k in path for k in ("bq", "bk", "bv")):
        return P("model")
    if "in_proj" in path:
        return P(f, "model")
    if "out_proj" in path:
        return P("model", f)
    if "conv_w" in path:
        return P(None, "model")
    if any(k in path for k in ("A_log", "dt_bias")):
        return P("model")
    if any(k in path for k in ("D_skip", "norm_w")):
        return P("model")
    return P()  # norms, scalars: replicated


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, params_tree: Any, mesh: Mesh):
    """PartitionSpec pytree matching `params_tree` (abstract or concrete)."""
    fsdp = batch_axes(mesh) if len(batch_axes(mesh)) > 1 else batch_axes(mesh)[0]

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def axis_size(a) -> int:
        if a is None:
            return 1
        if isinstance(a, tuple):
            n = 1
            for x in a:
                n *= sizes[x]
            return n
        return sizes[a]

    def spec_for(path, leaf):
        ps = _path_str(path)
        stacked = "layers" in ps            # scan-stacked: leading block dim
        nd = len(leaf.shape) - (1 if stacked else 0)
        rule = _param_rule(ps, nd, cfg, fsdp, sizes["model"])
        if stacked:
            rule = P(None, *rule)
        # pad/trim to the leaf rank (biases, scalars)
        rule = tuple(rule)[: len(leaf.shape)]
        rule = rule + (None,) * (len(leaf.shape) - len(rule))
        # divisibility guard: explicit pjit shardings require even splits —
        # replicate any dim the mesh axis doesn't divide (e.g. granite's 40
        # experts over model=16, hymba's fused in_proj width).
        rule = tuple(a if dim % axis_size(a) == 0 else None
                     for a, dim in zip(rule, leaf.shape))
        return P(*rule)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def param_shardings(cfg: ArchConfig, params_tree: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params_tree, mesh))


def data_specs(mesh: Mesh, tokens_shape: tuple[int, ...]) -> P:
    """Input token sharding: batch over DP axes (global batch permitting)."""
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in ba:
        dp *= sizes[a]
    if tokens_shape[0] % dp == 0:
        return P(ba, *([None] * (len(tokens_shape) - 1)))
    return P(*([None] * len(tokens_shape)))


def cache_specs(cfg: ArchConfig, cache_tree: Any, mesh: Mesh, batch: int):
    """KV/SSM cache sharding for serving.

    Batch-shardable cells shard batch over DP axes; the `long_500k` cell
    (batch=1) shards the KV *sequence* dim over `data` instead (sequence
    parallelism for the long-context cache). Paged pools
    (models.layers.PagedKVCache) shard their *page* dim over the DP axes —
    pages have no batch affinity, so the pool distributes like sequence
    parallelism regardless of batch — with KV heads over `model` exactly
    like dense rings; the per-slot block table is tiny and replicated
    (every page shard needs the full slot→page map to resolve gathers).
    """
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in ba:
        dp *= sizes[a]
    batch_ok = batch % dp == 0

    sizes_all = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fit(spec, shape):
        """Replicate dims the axis doesn't divide (explicit-sharding rule)."""
        def axis_size(a):
            if a is None:
                return 1
            axes = a if isinstance(a, tuple) else (a,)
            n = 1
            for x in axes:
                n *= sizes_all[x]
            return n
        return P(*(a if d % axis_size(a) == 0 else None
                   for a, d in zip(tuple(spec), shape)))

    def kv_head_specs(kvh: int):
        """Padded caches shard on heads (matches the attention compute —
        no per-step reshard); unpadded fall back to the head *dim*."""
        if kvh % sizes_all.get("model", 1) == 0:
            return "model", None
        return None, "model"

    def spec_for(path, leaf):
        from repro.models.layers import PagedKVCache
        if isinstance(leaf, PagedKVCache):
            kv_spec, hd_spec = kv_head_specs(leaf.k.shape[3])
            pool = P(None, ba, None, kv_spec, hd_spec)
            return PagedKVCache(
                k=fit(pool, leaf.k.shape),
                v=fit(pool, leaf.v.shape),
                positions=fit(P(None, ba, None), leaf.positions.shape),
                block_table=P(None, None, None))
        ps = _path_str(path)
        nd = len(leaf.shape)
        if ps.endswith("positions"):
            # per-slot positions: (n_super, B, S) — batch rows follow the
            # k/v batch sharding; long-context (batch=1) shards S over data
            if nd == 3:
                if batch_ok:
                    return fit(P(None, ba, None), leaf.shape)
                return fit(P(None, None, "data"), leaf.shape)
            return P(*([None] * nd))
        if "ssm" in ps:
            if nd == 5:   # state: (n_super, B, H, P, N) — TP on head dim P
                return fit(P(None, ba if batch_ok else None, None, "model",
                             None), leaf.shape)
            if nd == 4:   # conv tail: (n_super, B, KW-1, conv_dim)
                return fit(P(None, ba if batch_ok else None, None, "model"),
                           leaf.shape)
            return P(*([None] * nd))
        if nd == 5:       # k/v: (n_super, B, S, KVH, hd)
            kv_spec, hd_spec = kv_head_specs(leaf.shape[3])
            if batch_ok:
                return fit(P(None, ba, None, kv_spec, hd_spec), leaf.shape)
            # long-context: sequence parallelism over `data`
            return fit(P(None, None, "data", kv_spec, hd_spec), leaf.shape)
        return P(*([None] * nd))

    from repro.models.layers import PagedKVCache
    return jax.tree_util.tree_map_with_path(
        spec_for, cache_tree,
        is_leaf=lambda x: isinstance(x, PagedKVCache))


def activation_spec(mesh: Mesh, batch: int):
    """with_sharding_constraint target for the residual stream."""
    ba = batch_axes(mesh)
    return P(ba, None, None)


def handoff_frag_specs(cfg: ArchConfig, frag_tree: Any, mesh: Mesh):
    """PartitionSpecs for a dense batch-1 prefill fragment being handed
    off to a paged pool (disaggregated serving, DESIGN.md §10).

    The pool shards KV heads over `model` (`cache_specs`), so the
    fragment matches on the head dims — the page scatter then never
    reshards the head axis. The token dim is deliberately REPLICATED over
    the data axes: the pool's *page* dim is data-sharded and a fragment's
    pages scatter to arbitrary page slots, so each data shard needs
    exactly the whole pages that land in its page range — moving the
    (small, whole-page-quantized) fragment to every data shard IS the
    handoff's `device_put`, and the scatter keeps the rows local to each
    shard. Granularity is whole pages by construction: no per-token
    traffic. `cache_specs(batch=1)`'s sequence-parallel fallback is wrong
    here — it would split a page's rows across data shards and force a
    gather inside the scatter."""
    from repro.models.layers import KVCache
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fit(spec, shape):
        def axis_size(a):
            if a is None:
                return 1
            axes = a if isinstance(a, tuple) else (a,)
            n = 1
            for x in axes:
                n *= sizes[x]
            return n
        return P(*(a if d % axis_size(a) == 0 else None
                   for a, d in zip(tuple(spec), shape)))

    def spec_for(leaf):
        if isinstance(leaf, KVCache):
            # k/v: (n_super, 1, S, KVH, hd) — heads like the pool, token
            # dim replicated (see docstring)
            if leaf.k.shape[3] % sizes.get("model", 1) == 0:
                kv_spec, hd_spec = "model", None
            else:
                kv_spec, hd_spec = None, "model"
            kv = P(None, None, None, kv_spec, hd_spec)
            return KVCache(k=fit(kv, leaf.k.shape),
                           v=fit(kv, leaf.v.shape),
                           positions=P(None, None, None))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(spec_for, frag_tree,
                        is_leaf=lambda x: isinstance(x, KVCache))


def reshard_handoff(frag: Any, mesh: Mesh | None, cfg: ArchConfig):
    """`device_put` a staged prefill fragment onto the pool-compatible
    layout (`handoff_frag_specs`) — the explicit page-handoff transfer of
    the disaggregated serve loop (ServeEngine._serve two-pool path).
    Identity when no mesh is given (single-host CPU engines)."""
    if mesh is None:
        return frag
    specs = handoff_frag_specs(cfg, frag, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        frag, specs)
