"""Checkpointing: async, atomic, content-hashed, elastic on restore.

Layout (one directory per step):

    <dir>/step_000000123/
        manifest.msgpack    tree structure, shapes, dtypes, sha256 per leaf
        arr_00000.npy ...   one file per leaf
    <dir>/latest            text file → step directory name (atomic rename)

Properties needed at 1000+ nodes, scaled to this container honestly:
  * **atomicity** — written to `<name>.tmp`, fsync'd, then renamed; `latest`
    updated last. A preempted writer never corrupts the previous checkpoint.
  * **async** — `save_async` snapshots to host RAM (device_get) and writes on
    a background thread; the train loop blocks only on the snapshot.
  * **integrity** — sha256 per leaf, verified on restore.
  * **elastic reshard-on-load** — leaves are stored as full logical arrays
    and `restore(..., shardings=...)` lays them out on whatever mesh is
    alive (different device count than the writer is fine). At true 400 B
    scale one would write per-shard files; the manifest already records
    enough metadata to extend to that (documented limitation).
"""
from __future__ import annotations

import hashlib
import os
import threading

import jax
import ml_dtypes
import msgpack
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve numpy + ml_dtypes (bfloat16, fp8) dtype names."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, extra: dict | None = None) -> str:
    """Synchronous atomic save. Returns the checkpoint directory."""
    os.makedirs(path, exist_ok=True)
    name = f"step_{step:09d}"
    tmp = os.path.join(path, name + ".tmp")
    final = os.path.join(path, name)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "extra": extra or {},
        "leaves": [],
    }
    host = [np.asarray(jax.device_get(x)) for x in leaves]
    for i, arr in enumerate(host):
        fn = f"arr_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
        })
    with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    # pointer file last — readers never see a partial checkpoint
    ptr_tmp = os.path.join(path, "latest.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(path, "latest"))
    return final


class AsyncSaver:
    """Snapshot-now, write-later checkpointing."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, path: str, step: int, tree, extra=None):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._thread = threading.Thread(
            target=save, args=(path, step, snapshot, extra), daemon=False)
        self._thread.start()


def latest_step(path: str) -> int | None:
    ptr = os.path.join(path, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        return int(f.read().strip().split("_")[-1])


def restore(path: str, target_tree, step: int | None = None,
            shardings=None, verify: bool = True):
    """Load into the structure of `target_tree` (abstract or concrete).

    `shardings`: optional matching pytree of NamedShardings — the elastic
    path: arrays are device_put onto the *current* mesh regardless of the
    topology that wrote them.
    """
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    cdir = os.path.join(path, f"step_{step:09d}")
    with open(os.path.join(cdir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    leaves, treedef = _flatten(target_tree)
    assert len(leaves) == len(manifest["leaves"]), (
        len(leaves), len(manifest["leaves"]), "tree structure changed")
    out = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    for meta, ref, shard in zip(manifest["leaves"], leaves, shard_leaves):
        arr = np.load(os.path.join(cdir, meta["file"]))
        if arr.dtype.kind == "V":      # npy stores bf16/fp8 as raw void
            arr = arr.view(_np_dtype(meta["dtype"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {meta['file']}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
    return treedef.unflatten(out), manifest["extra"], step
