"""Optimizer library (no optax in this environment — built from scratch).

AdamW with fp32 master statistics, global-norm clipping, and warmup+cosine
schedules. API mirrors the (init, update) convention so tests can check
against a numpy reference step-by-step.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def constant_lr(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


class AdamWState(NamedTuple):
    count: jax.Array
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamWState(count=jnp.zeros((), jnp.int32), mu=z,
                          nu=jax.tree.map(jnp.copy, z))

    def update(self, grads, state: AdamWState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(grads)))
        scale = (jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))
                 if self.clip_norm else 1.0)
        grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)
        c = count.astype(jnp.float32)
        bc1, bc2 = 1 - b1 ** c, 1 - b2 ** c
        lr = self.schedule(count)

        def step(p, m, v):
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            upd = upd + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * upd).astype(p.dtype)

        new_params = jax.tree.map(step, params, mu, nu)
        return (new_params, AdamWState(count=count, mu=mu, nu=nu),
                {"grad_norm": gnorm, "lr": lr})
