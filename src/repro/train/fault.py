"""Fault-tolerance utilities: preemption handling + straggler watchdog.

* `PreemptionGuard` — installs SIGTERM/SIGINT handlers; the train loop polls
  `should_stop` and checkpoints before exiting (graceful preemption — the
  standard TPU-pod eviction contract).
* `StragglerWatchdog` — tracks per-step wall times; a step slower than
  `threshold ×` the running median is logged as a straggler event, and a
  callback (e.g. "checkpoint now + request reschedule") can be attached.
  On a real fleet this is fed per-host; here it watches the single process
  but keeps the fleet-shaped API.
"""
from __future__ import annotations

import signal
import statistics
import time
from typing import Callable


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:        # non-main thread (tests)
                pass

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StragglerWatchdog:
    def __init__(self, threshold: float = 2.5, window: int = 50,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.threshold = threshold
        self.window = window
        self.on_straggler = on_straggler
        self.times: list[float] = []
        self.events: list[tuple[int, float, float]] = []
        self._t0: float | None = None

    def step_start(self):
        self._t0 = time.perf_counter()

    def step_end(self, step: int) -> float:
        dt = time.perf_counter() - self._t0
        med = statistics.median(self.times) if self.times else dt
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        if len(self.times) >= 5 and dt > self.threshold * med:
            self.events.append((step, dt, med))
            if self.on_straggler:
                self.on_straggler(step, dt, med)
        return dt
