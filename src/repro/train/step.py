"""Train/serve step factories.

`make_train_step` builds the jitted SPMD step: microbatch gradient
accumulation via `lax.scan` (lets XLA overlap each microbatch's gradient
reduce-scatter with the next microbatch's compute), remat inside the model's
superblock scan, AdamW on fp32 masters.

`make_serve_step` / `make_prefill_step` build the decode-path steps lowered
by the `decode_*` / `long_*` dry-run cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models import model as M
from .optimizer import AdamW
from .train_state import TrainState


def make_train_step(cfg: ArchConfig, opt: AdamW, accum_steps: int = 1,
                    aux_weight: float = 0.01):
    """Returns train_step(state, batch) → (state, metrics).

    batch: {"tokens": (B, T) i32, "labels": (B, T) i32,
            optional "frontend": (B, S, d) f32}.
    With accum_steps > 1, B must divide evenly; gradients are accumulated
    over accum_steps microbatches in fp32.
    """

    def loss_fn(params, mb):
        loss, nll = M.lm_loss(params, cfg, mb["tokens"], mb["labels"],
                              frontend_embeds=mb.get("frontend"),
                              aux_weight=aux_weight)
        return loss, nll

    def train_step(state: TrainState, batch):
        params = state.params

        if accum_steps == 1:
            (loss, nll), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                # strided µbatches: row j of µbatch i is global row j·A + i,
                # so each µbatch stays sharded across the FULL data axis
                # (a contiguous reshape would split the DP axis between the
                # scan dim and the batch dim — 8× the live activation set).
                mb = x.shape[0] // accum_steps
                return x.reshape(mb, accum_steps, *x.shape[1:]).swapaxes(0, 1)
            mbs = {k: split(v) for k, v in batch.items()}

            def accum(carry, mb):
                g_acc, l_acc, n_acc = carry
                (l, n), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + l, n_acc + n), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, nll), _ = lax.scan(
                accum, (g0, jnp.zeros(()), jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss, nll = loss / accum_steps, nll / accum_steps

        new_params, opt_state, om = opt.update(grads, state.opt_state, params)
        metrics = {"loss": loss, "nll": nll, **om}
        return TrainState(step=state.step + 1, params=new_params,
                          opt_state=opt_state), metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, prefix_len: int = 0):
    """prefill(params, tokens, cache, [frontend]) → (logits_last, cache).

    With `prefix_len > 0` (continued prefill — the serve engine's
    prefix-cache hits), `tokens` holds only a prompt's uncached suffix and
    the cache's first `prefix_len` rows are pre-loaded shared-prefix KV;
    rope positions, the cache write offset, and the attention masks all
    start at `prefix_len` (model.forward / layers.attention_block)."""

    def prefill(params, tokens, cache, frontend=None):
        logits, cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                     frontend_embeds=frontend,
                                     last_only=True, prefix_len=prefix_len)
        return logits, cache

    return prefill


def make_bucketed_prefill_step(cfg: ArchConfig, prefix_len: int = 0):
    """Bucketed prefill for the serve engine's prompt-length bucketing
    (DESIGN.md §10 satellite): `tokens` is a suffix right-padded up to a
    bucket length, so mixed prompt lengths share one jit trace per
    (prefix_len, bucket) instead of retracing per distinct length.

    prefill(params, tokens, cache, last_idx, valid_len) →
    (logits_last, cache):

    * `last_idx` (traced) is the real suffix's last row — the lm_head runs
      on that row, not the padded block's end (model.forward last_index);
    * `valid_len` (traced) is the real ABSOLUTE prompt length: every cache
      row at position >= valid_len had its K/V computed from padding, so
      its position is forced to -1 after the forward — invisible to the
      attention mask (layers.decode_attention masks kv_positions >= 0),
      exactly like an empty ring entry, and overwritten in place once the
      request decodes past it. Real rows never see the padded ones
      (causal masking), so their K/V and the selected logits row come out
      of the same arithmetic as an exact-length prefill.

    Only sound for attention-only stacks: right-padding would advance
    ssm/hybrid recurrent state through garbage tokens, and local-window
    ring writes past the real length could wrap onto live rows — the
    engine gates bucketing off for those (ServeEngine.bucketing_on)."""
    from repro.models.layers import KVCache

    def prefill(params, tokens, cache, last_idx, valid_len, frontend=None):
        logits, cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                     frontend_embeds=frontend,
                                     last_only=True, last_index=last_idx,
                                     prefix_len=prefix_len)

        def mask(leaf):
            if not isinstance(leaf, KVCache):
                return leaf
            S = leaf.positions.shape[-1]
            keep = jnp.arange(S, dtype=jnp.int32) < valid_len
            return leaf._replace(
                positions=jnp.where(keep, leaf.positions, -1))

        cache = jax.tree.map(mask, cache,
                             is_leaf=lambda x: isinstance(x, KVCache))
        return logits, cache

    return prefill


def make_serve_step(cfg: ArchConfig):
    """One decode step: (params, token (B,1), cache, pos (B,), [frontend]) →
    (logits (B,1,V), new_cache). The `decode_*`/`long_*` dry-run target.

    `pos` is a per-slot position vector — under continuous batching each
    batch row serves an independent request at its own depth (scalars are
    broadcast for single-sequence callers)."""

    def serve_step(params, token, cache, pos, frontend=None):
        logits, cache, _ = M.forward(params, cfg, token, cache=cache,
                                     pos=pos, frontend_embeds=frontend)
        return logits, cache

    return serve_step


def make_draft_step(cfg: ArchConfig, draft_layers: int):
    """Early-exit decode step for self-speculative drafting (DESIGN.md §9):
    run only the first `draft_layers` superblocks of the *same* params —
    no second model — then the shared final norm + lm_head.

    (params, token (B,1), cache, pos (B,)) → (logits (B,1,V), new_cache).
    The returned cache merges the draft's early-superblock KV writes back
    into the full-depth cache tree: consecutive draft steps must see each
    other's keys, and the verify forward later overwrites every position
    the draft wrote (all layers, pos..pos+k ⊇ early layers, pos..pos+k-1),
    so a rejected draft leaves no live state behind.
    """
    E = draft_layers

    def draft_step(params, token, cache, pos):
        p = dict(params)
        p["layers"] = jax.tree.map(lambda x: x[:E], params["layers"])
        sub = jax.tree.map(lambda x: x[:E], cache)
        logits, new_sub, _ = M.forward(p, cfg, token, cache=sub, pos=pos)
        cache = jax.tree.map(lambda full, new: full.at[:E].set(new),
                             cache, new_sub)
        return logits, cache

    return draft_step


def make_verify_step(cfg: ArchConfig):
    """Batched speculative verify: (params, tokens (B, k+1), cache,
    pos (B,)) → (logits (B, k+1, V), new_cache). Column 0 is each slot's
    last emitted token, columns 1..k the draft; one full-depth forward in
    decode_multi mode scores all k+1 next-token distributions while
    writing KV at pos..pos+k per slot."""

    def verify_step(params, tokens, cache, pos):
        logits, cache, _ = M.forward(params, cfg, tokens, cache=cache,
                                     pos=pos, decode_multi=True)
        return logits, cache

    return verify_step
