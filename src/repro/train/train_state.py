"""Train state pytree + abstract construction for the dry-run."""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import model as M
from .optimizer import AdamW, AdamWState


class TrainState(NamedTuple):
    step: jax.Array
    params: Any          # fp32 masters
    opt_state: AdamWState


def init_state(rng, cfg: ArchConfig, opt: AdamW) -> TrainState:
    params = M.init_params(rng, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt_state=opt.init(params))


def abstract_state(cfg: ArchConfig, opt: AdamW) -> TrainState:
    """ShapeDtypeStruct state — the dry-run's zero-allocation stand-in."""
    return jax.eval_shape(
        functools.partial(init_state, cfg=cfg, opt=opt), jax.random.key(0))
