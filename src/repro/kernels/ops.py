"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile to Mosaic. `INTERPRET` is resolved once from the backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sa_matmul import sa_matmul_pallas
from .fp_emu import fma_emu_matmul
from .quantize import quantize_fp8, amax_scale
from .sa_attention import sa_attention as _sa_attention

INTERPRET = jax.default_backend() != "tpu"


def sa_attention(q, k, v, **kw):
    """Flash attention kernel (VMEM-resident softmax state; see
    sa_attention.py). Forward-only; GQA/causal/window/softcap."""
    kw.setdefault("interpret", INTERPRET)
    return _sa_attention(q, k, v, **kw)


def sa_matmul(a: jax.Array, w: jax.Array, *, bm: int = 256, bn: int = 256,
              bk: int = 512, out_dtype=jnp.float32) -> jax.Array:
    """Production GEMM under the SA contract (see sa_matmul.py)."""
    return sa_matmul_pallas(a, w, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                            interpret=INTERPRET)


def sa_matmul_fp8(a: jax.Array, w: jax.Array, fmt_name: str = "fp8_e4m3",
                  **kw) -> jax.Array:
    """FP8 GEMM: per-tensor-scaled quantization kernels feeding the SA GEMM,
    descaled on output (round-once preserved end-to-end)."""
    sa_, sw = amax_scale(a, fmt_name), amax_scale(w, fmt_name)
    aq = quantize_fp8(a, sa_, fmt_name, interpret=INTERPRET).astype(jnp.bfloat16)
    wq = quantize_fp8(w, sw, fmt_name, interpret=INTERPRET).astype(jnp.bfloat16)
    y = sa_matmul(aq, wq, **kw)
    return y * (sa_ * sw)


def skewed_datapath_matmul(a: jax.Array, w: jax.Array,
                           fmt_name: str = "bf16") -> jax.Array:
    """Bit-exact skewed-pipeline GEMM (validation path; see fp_emu.py)."""
    return fma_emu_matmul(a, w, fmt_name, interpret=True)


__all__ = ["sa_matmul", "sa_matmul_fp8", "skewed_datapath_matmul",
           "sa_attention", "quantize_fp8", "amax_scale", "INTERPRET"]
