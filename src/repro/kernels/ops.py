"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on TPU they
compile to Mosaic. `INTERPRET` is resolved once from the backend.

`sa_matmul` is the production GEMM path: differentiable (custom VJP through
the same round-once kernel), fused-epilogue capable (bias/act/scale before
the single output rounding), and block-shape autotuned via
`repro.kernels.autotune` whenever the caller doesn't pin (bm, bn, bk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import autotune
from .sa_matmul import sa_matmul_pallas
from .fp_emu import fma_emu_matmul
from .quantize import quantize_fp8, amax_scale
from .sa_attention import sa_attention as _sa_attention
from .sa_decode_attention import (
    fused_decode_supported,
    sa_paged_decode_attention as _sa_paged_decode_attention,
)

INTERPRET = jax.default_backend() != "tpu"


def sa_attention(q, k, v, **kw):
    """Flash attention kernel (VMEM-resident softmax state; see
    sa_attention.py). Forward-only; GQA/causal/window/softcap."""
    kw.setdefault("interpret", INTERPRET)
    return _sa_attention(q, k, v, **kw)


def paged_decode_attention(q, k_pool, v_pool, page_positions, block_table,
                           pos, **kw):
    """Fused paged decode attention (see sa_decode_attention.py): walks the
    block table inside the kernel instead of gathering a dense view in HBM.
    Bit-identical to `gather_pages` + `decode_attention`; grid shapes
    (pages_per_block, head tiling) resolve through the autotune cache."""
    kw.setdefault("interpret", INTERPRET)
    return _sa_paged_decode_attention(q, k_pool, v_pool, page_positions,
                                      block_table, pos, **kw)


def sa_matmul(a: jax.Array, w: jax.Array, *, bias: jax.Array | None = None,
              act: str = "none", scale=None, bm: int | None = None,
              bn: int | None = None, bk: int | None = None,
              out_dtype=jnp.float32, mode: str = "exact") -> jax.Array:
    """Production GEMM under the SA contract (see sa_matmul.py).

    Unpinned block dims are resolved through the autotune cache (tuned entry
    if one exists for this (M, N, K, dtype, epilogue), MXU heuristic
    otherwise; set REPRO_AUTOTUNE=1 to sweep on miss).

    ``mode="approx"`` selects the bulk-tier approximate-normalization
    arithmetic (accumulator guard bits truncated before the single
    rounding; see sa_matmul.APPROX_DROP_BITS).
    """
    m, k = a.shape
    n = w.shape[1]
    if bm is None or bn is None or bk is None:
        tbm, tbn, tbk = autotune.lookup(m, n, k, dtype=str(a.dtype),
                                        epilogue=act)
        bm, bn, bk = bm or tbm, bn or tbn, bk or tbk
    return sa_matmul_pallas(a, w, bias, scale, act=act, bm=bm, bn=bn, bk=bk,
                            out_dtype=out_dtype, interpret=INTERPRET,
                            mode=mode)


def sa_matmul_fp8(a: jax.Array, w: jax.Array, fmt_name: str = "fp8_e4m3",
                  **kw) -> jax.Array:
    """FP8 GEMM: per-tensor-scaled quantization kernels feeding the SA GEMM.
    The descale (sa·sw) rides the fused epilogue — applied to the fp32 chain
    *before* the single output rounding (round-once preserved end-to-end)."""
    sa_, sw = amax_scale(a, fmt_name), amax_scale(w, fmt_name)
    aq = quantize_fp8(a, sa_, fmt_name, interpret=INTERPRET).astype(jnp.bfloat16)
    wq = quantize_fp8(w, sw, fmt_name, interpret=INTERPRET).astype(jnp.bfloat16)
    return sa_matmul(aq, wq, scale=sa_ * sw, **kw)


def skewed_datapath_matmul(a: jax.Array, w: jax.Array,
                           fmt_name: str = "bf16",
                           mode: str = "exact") -> jax.Array:
    """Bit-exact skewed-pipeline GEMM (validation path; see fp_emu.py).
    ``mode="approx"`` selects the approximate-normalization datapath."""
    return fma_emu_matmul(a, w, fmt_name, interpret=True, mode=mode)


__all__ = ["sa_matmul", "sa_matmul_fp8", "skewed_datapath_matmul",
           "sa_attention", "paged_decode_attention",
           "fused_decode_supported", "quantize_fp8", "amax_scale",
           "autotune", "INTERPRET"]
