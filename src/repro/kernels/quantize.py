"""Pallas kernel: scaled FP8 quantization (the SA's input formatting stage).

Quantizes f32/bf16 tensors onto an FP8 grid (E4M3/E5M2, Fig. 1) with a
per-tensor scale: ``y = rne(x / scale)`` with FTZ + saturation. In the fp8
GEMM path this runs in the tile prologue, so the "exponent work" (scale +
format handling) of tile k+1 overlaps the MXU work of tile k — the software
analogue of the paper's speculative exponent forwarding (DESIGN.md §2b).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.fpformats import get_format


def _quant_body(x, *, man_bits: int, min_normal: float, max_finite: float,
                saturate: bool):
    bits = lax.bitcast_convert_type(x, jnp.uint32)
    shift = 23 - man_bits
    half = jnp.uint32(1 << (shift - 1))
    lsb = (bits >> shift) & 1
    rounded = (bits + half - 1 + lsb) & ~jnp.uint32((1 << shift) - 1)
    y = lax.bitcast_convert_type(rounded, jnp.float32)
    ay = jnp.abs(y)
    y = jnp.where(ay < min_normal, 0.0, y)                     # FTZ
    if saturate:
        y = jnp.clip(y, -max_finite, max_finite)
    else:
        y = jnp.where(ay > max_finite, jnp.sign(y) * jnp.inf, y)
    return jnp.where(jnp.isnan(x), x, y)


def _quantize_kernel(x_ref, scale_ref, o_ref, **params):
    inv = 1.0 / scale_ref[0]
    o_ref[...] = _quant_body(x_ref[...] * inv, **params)


@functools.partial(jax.jit, static_argnames=("fmt_name", "block", "interpret"))
def quantize_fp8(x: jax.Array, scale: jax.Array, fmt_name: str = "fp8_e4m3",
                 *, block: int = 512, interpret: bool = False) -> jax.Array:
    """Quantize `x/scale` onto the fp8 grid; returns f32 grid values."""
    fmt = get_format(fmt_name)
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    bl = min(block, n)
    params = dict(man_bits=fmt.man_bits, min_normal=fmt.min_normal,
                  max_finite=fmt.max_finite, saturate=fmt.saturate)
    out = pl.pallas_call(
        functools.partial(_quantize_kernel, **params),
        grid=(pl.cdiv(n, bl),),
        in_specs=[
            pl.BlockSpec((bl,), lambda i: (i,)),
            pl.BlockSpec(memory_space=pl.ANY),   # scalar scale, unblocked
        ],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(flat, jnp.asarray(scale, jnp.float32).reshape(1))
    return out.reshape(orig_shape)


def amax_scale(x: jax.Array, fmt_name: str = "fp8_e4m3") -> jax.Array:
    """Per-tensor scale mapping amax onto the format's max finite value."""
    fmt = get_format(fmt_name)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    return jnp.maximum(amax / fmt.max_finite, 1e-12)
