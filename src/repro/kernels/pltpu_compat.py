"""Compatibility shims for `jax.experimental.pallas.tpu` API renames."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# renamed across jax releases: TPUCompilerParams (≤0.4.x) → CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:  # pragma: no cover - depends on jax version
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported by the Pallas "
        "kernels (need jax>=0.4.30)")
