"""Pallas kernel running the paper's skewed exponent datapath bit-exactly.

Where `sa_matmul.py` maps the paper's *insight* onto the MXU, this kernel
executes the paper's *exact integer datapath* (§III.B, Figs. 5/6) — the
speculative exponent forward ``ê_i = max(e_Mi, ê_{i-1})``, the one-stage-late
LZA forward ``L_{i-1}``, the fix ``d = d' ± L_{i-1}``, and the retimed
normalize∥align net shift — tile-parallel over the output matrix, with the
K loop playing the column of PEs.

It is the on-device twin of :mod:`repro.core.chained_fma` (the numpy model is
the oracle in `tests/test_kernels.py`), and is used to bit-audit the MXU
path: for inputs where no alignment truncation occurs the two agree exactly.

All state is int32: the accumulator register is GUARD+24 = 27 bits
(msb ≤ P+1 = 27 < 31), exponents are small integers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.chained_fma import ACC_MSB, APPROX_COARSE, E_ZERO, GUARD
from repro.core.fpformats import get_format

# E_ZERO is imported from the numpy twin (a python int, so it folds into the
# kernel rather than being captured): the two models must share one zero
# sentinel or their bit-exactness contract drifts (tests/test_kernels.py
# asserts they agree).
_Q = ACC_MSB + 1


def _msb(x):
    """floor(log2(x)) for int32 x > 0 (exact clz-style binary search)."""
    m = jnp.zeros_like(x)
    for shift in (16, 8, 4, 2, 1):
        hi = x >> shift
        gt = hi > 0
        x = jnp.where(gt, hi, x)
        m = m + jnp.where(gt, shift, 0)
    return m


def _shr(x, n):
    return x >> jnp.clip(n, 0, 31)


def _shl(x, n):
    return x << jnp.clip(n, 0, 31)


def _net_shift(x, left):
    """The retimed bidirectional normalize∥align shifter of Fig. 6."""
    return jnp.where(left >= 0, _shl(x, left), _shr(x, -left))


def _fields(xf32, man_bits: int):
    """Extract (s, e_unbiased, mantissa-with-hidden) — values must already be
    representable in the reduced format (truncation is then exact)."""
    bits = lax.bitcast_convert_type(xf32, jnp.uint32)
    s = (bits >> 31).astype(jnp.int32)
    e32 = ((bits >> 23) & 0xFF).astype(jnp.int32)
    frac = ((bits >> (23 - man_bits)) & ((1 << man_bits) - 1)).astype(jnp.int32)
    m = jnp.where(e32 > 0, frac | (1 << man_bits), 0)
    e = jnp.where(m == 0, E_ZERO, e32 - 127)
    return s, e, m


def _fma_emu_kernel(a_ref, w_ref, o_ref, *, n_k: int, man_bits: int,
                    approx: bool):
    a_blk = a_ref[...]        # (bm, K) f32 values on the reduced grid
    w_blk = w_ref[...]        # (K, bn)
    bm, bn = o_ref.shape

    def pe_step(k, carry):
        s_p, ehat, S, L = carry
        av = lax.dynamic_slice_in_dim(a_blk, k, 1, axis=1)      # (bm, 1)
        wv = lax.dynamic_slice_in_dim(w_blk, k, 1, axis=0)      # (1, bn)
        sa, ea, ma = _fields(av, man_bits)
        sb, eb, mb = _fields(wv, man_bits)
        # --- stage 1: multiplier (exact in the wide register) -------------
        mm = ma * mb                                            # (bm, bn)
        pm_msb = _msb(jnp.maximum(mm, 1))
        e_m = ea + eb - 2 * man_bits + pm_msb
        m_m = _shl(mm, ACC_MSB - pm_msb)
        s_m = sa ^ sb
        e_m = jnp.where(mm == 0, E_ZERO, e_m)
        # --- stage 1: speculative exponent compute (uses ê, not e) --------
        ge = e_m >= ehat
        d_spec = jnp.abs(e_m - ehat)
        # --- stage 2: fix with the forwarded L of the previous PE ---------
        d_fix = jnp.where(ge, d_spec + L, L - d_spec)
        prod_dom = d_fix > 0
        zero_prev = S == 0
        e_max = jnp.where(prod_dom, e_m, ehat - L)
        e_max = jnp.where(zero_prev, e_m, e_max)
        # retimed normalize ∥ align: one net shift of the incoming sum
        acc_net_left = (L - 1) - jnp.maximum(d_fix, 0)
        Sa = jnp.where(zero_prev, 0, _net_shift(S, acc_net_left))
        mp = jnp.where(e_m == E_ZERO, 0, _shr(m_m, jnp.maximum(-d_fix, 0)))
        # --- adder + LZA ---------------------------------------------------
        v = jnp.where(s_m == 1, -mp, mp) + jnp.where(s_p == 1, -Sa, Sa)
        s_o = (v < 0).astype(jnp.int32)
        S_o = jnp.abs(v)
        L_o = _Q - _msb(jnp.maximum(S_o, 1))
        if approx:
            # approximate normalization (arxiv 2408.11997): coarse LZA —
            # forward only the high bits of the count, leaving up to
            # APPROX_COARSE−1 leading zeros unnormalized in the wide
            # accumulator (same arithmetic as chained_fma.approx_pe)
            L_o = L_o & ~(APPROX_COARSE - 1)
        z = S_o == 0
        return (jnp.where(z, 0, s_o),
                jnp.where(z, E_ZERO, e_max + 1),
                S_o,
                jnp.where(z, 0, L_o))

    init = (jnp.zeros((bm, bn), jnp.int32),
            jnp.full((bm, bn), E_ZERO, jnp.int32),
            jnp.zeros((bm, bn), jnp.int32),
            jnp.zeros((bm, bn), jnp.int32))
    s, ehat, S, L = lax.fori_loop(0, n_k, pe_step, init)

    # column-end: deferred final normalization + the single rounding stage
    Ln = _Q - _msb(jnp.maximum(S, 1))
    e = ehat - Ln
    m = _net_shift(S, Ln - 1)
    low = m & ((1 << GUARD) - 1)
    keep = m >> GUARD
    half = 1 << (GUARD - 1)
    up = (low > half) | ((low == half) & ((keep & 1) == 1))
    keep = keep + up.astype(jnp.int32)
    ovf = (keep >> 24) != 0
    keep = jnp.where(ovf, keep >> 1, keep)
    e = e + ovf.astype(jnp.int32)
    # bit-exact f32 construction (exp2/mul would round): keep has its hidden
    # bit at 23, e is the unbiased exponent. FTZ below the normal range,
    # saturate to Inf above it (documented output contract).
    e32 = e + 127
    frac = (keep & 0x7FFFFF).astype(jnp.uint32)
    bits = ((s.astype(jnp.uint32) << 31)
            | (jnp.clip(e32, 0, 255).astype(jnp.uint32) << 23) | frac)
    bits = jnp.where(e32 >= 255,
                     (s.astype(jnp.uint32) << 31) | jnp.uint32(0x7F800000),
                     bits)
    zero = (S == 0) | (e32 <= 0)
    bits = jnp.where(zero, s.astype(jnp.uint32) << 31, bits)
    o_ref[...] = lax.bitcast_convert_type(bits, jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("fmt_name", "bm", "bn", "interpret",
                                    "mode"))
def fma_emu_matmul(a: jax.Array, w: jax.Array, fmt_name: str = "bf16", *,
                   bm: int = 64, bn: int = 64, interpret: bool = True,
                   mode: str = "exact"):
    """(M,K)@(K,N) through the bit-exact skewed datapath, tile-parallel.

    K is kept resident per block (this kernel demonstrates the PE chain; it
    is not the production GEMM path — that is `sa_matmul`).

    ``mode="approx"`` runs the approximate-normalization variant (coarse
    LZA forward; the on-device twin of `chained_fma.approx_chain`).
    """
    if mode not in ("exact", "approx"):
        raise ValueError(f"mode={mode!r}; want 'exact' or 'approx'")
    fmt = get_format(fmt_name)
    m, k = a.shape
    _, n = w.shape
    bm, bn = min(bm, m), min(bn, n)
    kernel = pl.pallas_call(
        functools.partial(_fma_emu_kernel, n_k=k, man_bits=fmt.man_bits,
                          approx=(mode == "approx")),
        grid=(pl.cdiv(m, bm), pl.cdiv(n, bn)),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )
    return kernel(a.astype(jnp.float32), w.astype(jnp.float32))
