"""Pure-jnp oracles for the Pallas kernels (no pallas imports)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fpformats import get_format


def sa_matmul_ref(a: jax.Array, w: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """The SA arithmetic contract in plain jnp: products accumulated in fp32,
    rounded once on write-out."""
    y = jnp.matmul(a, w, preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def quantize_ref(x: jax.Array, fmt_name: str, scale: jax.Array | float = 1.0
                 ) -> jax.Array:
    """Scaled quantization oracle: round(x/scale) onto the format grid (RNE,
    FTZ, saturating per format), returned as f32 values on the grid."""
    from repro.core.fpformats import quantize

    return quantize(jnp.asarray(x, jnp.float32) / scale, get_format(fmt_name))


def chained_fma_ref(a: np.ndarray, w: np.ndarray, fmt_name: str = "bf16",
                    pipeline: str = "skewed") -> np.ndarray:
    """Bit-exact oracle for the fp_emu kernel: the numpy datapath model."""
    from repro.core.chained_fma import matmul_emulated

    return matmul_emulated(a, w, get_format(fmt_name), pipeline)
