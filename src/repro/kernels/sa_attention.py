"""Pallas TPU kernel: flash attention with the SA arithmetic contract.

The framework's jnp-level blockwise attention (models/layers.py) materializes
per-tile probabilities in HBM; this kernel keeps the entire online-softmax
state — running max, normalizer, and the **unnormalized** output accumulator —
in VMEM scratch across the KV grid dimension, normalizing exactly once at the
end. That is the paper's skewed-column principle applied to attention:
unnormalized accumulation across the chain, deferred normalization, one
rounding at the end (DESIGN.md §2b).

Forward-only (training uses the custom-VJP jnp path; serving/prefill are
forward). GQA via the kv-head index map (query head h reads kv head h//g).
Causal/sliding-window masks and logit softcap supported statically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pltpu_compat import CompilerParams as _CompilerParams


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 n_kv: int, bq: int, bkv: int, scale: float, causal: bool,
                 window: int, cap: float, q_offset: int):
    jkv = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(jkv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                # (bq, hd)
    k = k_ref[0, 0]                                # (bkv, hd)
    v = v_ref[0, 0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if cap:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                          (bq, bkv), 0)
    kv_pos = jkv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    ok = jnp.ones((bq, bkv), jnp.bool_)
    if causal:
        ok &= q_pos >= kv_pos
    if window:
        ok &= q_pos - kv_pos < window
    s = jnp.where(ok, s, -jnp.inf)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(s - m_safe[:, None])
    corr = jnp.exp(m_prev - m_safe)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    # unnormalized accumulate (the skewed-column contract): fp32 scratch,
    # no per-step normalization
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)

    @pl.when(jkv == n_kv - 1)
    def _normalize_once():
        o_ref[0, 0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "cap", "q_offset", "bq",
                              "bkv", "interpret"))
def sa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 causal: bool = True, window: int = 0, cap: float = 0.0,
                 q_offset: int = 0, bq: int = 512, bkv: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q: (B, H, T, hd); k, v: (B, KVH, S, hd) → (B, H, T, hd)."""
    B, H, T, hd = q.shape
    KVH, S = k.shape[1], k.shape[2]
    g = H // KVH
    scale = hd ** -0.5
    bq = min(bq, T)
    bkv = min(bkv, S)
    while T % bq:
        bq -= 1
    while S % bkv:
        bkv -= 1
    grid = (B, H, T // bq, S // bkv)

    kernel = pl.pallas_call(
        functools.partial(_attn_kernel, n_kv=grid[3], bq=bq, bkv=bkv,
                          scale=scale, causal=causal, window=window, cap=cap,
                          q_offset=q_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )
    return kernel(q, k, v)
