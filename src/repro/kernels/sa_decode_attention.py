"""Pallas TPU kernel: fused paged-attention decode (flash-decoding style).

The paged serving layout (DESIGN.md §5) stores KV in a global page pool with
per-slot block tables. The jnp decode path gathers every slot's mapped pages
into a dense ``(B, max_pages·page_size, KVH, hd)`` view in HBM each token,
each layer — a bandwidth tax proportional to the block-table capacity, not to
the tokens actually attended. This kernel walks ``block_table[b]`` directly:
the grid runs over (batch-slot, KV-head block, page block), and a
scalar-prefetch-driven index map fetches each step's pages straight from the
pool into VMEM, so the dense gathered view never exists in HBM — the paged
gather happens inside the kernel's memory hierarchy (the Gemmini
scratchpad/mvin idiom restated in Pallas).

Numerics are pinned **bit-for-bit** to ``gather_pages`` + ``decode_attention``
(tests/test_decode_kernel.py): scores run under the SA contract
(``PrecisionPolicy.cast_in`` per operand — elementwise, so quantizing page
blocks in VMEM ≡ quantizing the gathered view — fp32 accumulate, same
softcap/window/GQA semantics), the running row max is maintained online
across the page walk (max is order-invariant, so it is exact), and the
exponential/normalize/PV reduction is deferred to the final grid step — the
softmax analogue of the paper's round-once column: unnormalized state across
the chain, one normalization at the end. Unmapped block-table entries and the
reserved trash page (id 0) are masked inside the kernel: their score lanes
are written as -inf and their V lanes as 0 without touching the pool (a free
slot's garbage rows can hold NaNs — 0·NaN would poison the PV dot), and
``pl.when`` skips their score work entirely, which is why sparse block tables
get cheaper while the dense gather path keeps paying for full capacity.

Grid/block shapes are autotuned (`kernels/autotune.py`): ``pages_per_block``
(how many pages one grid step fetches — one BlockSpec per page offset, all
indexed through the prefetched block table) and ``heads_per_block`` (KV-head
tiling). Both must divide their axis; `sa_paged_decode_attention` clips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pltpu_compat import CompilerParams as _CompilerParams
from .sa_matmul import truncate_mantissa

_SUPPORTED_INPUT_FORMATS = ("fp32", "bf16", "fp16")
_INPUT_DTYPE = {"bf16": jnp.bfloat16, "fp16": jnp.float16}


def fused_decode_supported(policy) -> bool:
    """True when the fused kernel reproduces the jnp path for `policy`.

    FP8 inputs quantize through `fpformats.quantize` (grid snapping, not a
    dtype cast) and non-fp32 output formats round through the same machinery
    — both stay on the gather+dense path rather than re-implementing them
    in-kernel. `models/layers.py` consults this before dispatching.
    """
    return (policy.input_format in _SUPPORTED_INPUT_FORMATS
            and policy.output_format == "fp32")


def _exact_containers() -> bool:
    # read at trace time: the dry-run flips precision.EXACT_CPU_CONTAINERS
    # off in-process to lower the TPU-true bf16 program
    from repro.core import precision
    return precision.EXACT_CPU_CONTAINERS


def _cast_in(x, fmt: str):
    """`PrecisionPolicy.cast_in` restated for in-kernel use (fp32/bf16/fp16
    only — see `fused_decode_supported`). Elementwise, so casting each page
    block in VMEM is bit-identical to casting the gathered dense view."""
    if fmt == "fp32":
        return x.astype(jnp.float32)
    q = x.astype(_INPUT_DTYPE[fmt])
    return q.astype(jnp.float32) if _exact_containers() else q


def _container_dtype(fmt: str):
    """Dtype the cast-in operands (and the V scratch) actually carry."""
    if fmt == "fp32" or _exact_containers():
        return jnp.float32
    return _INPUT_DTYPE[fmt]


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of `n` that is <= cap (>= 1)."""
    d = max(1, min(int(cap), int(n)))
    while n % d:
        d -= 1
    return d


def _decode_kernel(bt_ref, pos_ref, q_ref, *refs, ppb: int, hb: int,
                   psz: int, n_steps: int, scale: float, window: int,
                   cap: float, fmt: str, approx: bool):
    """One grid step: fetch `ppb` pages for `hb` KV heads, score them into
    the (hb, g, S) score scratch, stage their cast-in V rows; the final step
    runs the deferred softmax + PV dot. refs layout (positional, after the
    two scalar-prefetch refs and the q ref): k×ppb, v×ppb, page-pos×ppb,
    out, score scratch, V scratch."""
    k_refs, v_refs = refs[:ppb], refs[ppb:2 * ppb]
    pp_refs = refs[2 * ppb:3 * ppb]
    o_ref, s_buf, v_buf = refs[3 * ppb], refs[3 * ppb + 1], refs[3 * ppb + 2]
    b = pl.program_id(0)
    j = pl.program_id(2)
    my_pos = pos_ref[b]
    q = _cast_in(q_ref[0], fmt)                       # (hb, g, hd)

    for i in range(ppb):
        slot = j * ppb + i                            # block-table column
        # id 0 is the reserved trash page: stale decode writes land there,
        # so an explicit 0 entry is as dead as an unmapped (-1) one
        mapped = bt_ref[b, slot] > 0
        k_ref, v_ref, pp_ref = k_refs[i], v_refs[i], pp_refs[i]

        @pl.when(mapped)
        def _score(k_ref=k_ref, v_ref=v_ref, pp_ref=pp_ref, slot=slot):
            k = _cast_in(k_ref[0], fmt)               # (psz, hb, hd)
            v = _cast_in(v_ref[0], fmt)
            kvp = pp_ref[0]                           # (psz,)
            ok = (kvp >= 0) & (kvp <= my_pos)
            if window:
                ok &= kvp > my_pos - window
            for t in range(hb):
                # per-head 2-D dot: contraction over hd in fp32, exactly the
                # per-(b, h) slice of the dense path's batched einsum
                s = jax.lax.dot_general(
                    q[t], k[:, t], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)
                if approx:
                    s = truncate_mantissa(s)
                # constants folded on the host: single-mul→tanh is the only
                # fusion-stable form (see decode_attention's softcap note)
                s = cap * jnp.tanh(s * (scale / cap)) if cap else s * scale
                s = jnp.where(ok[None, :], s, -jnp.inf)
                s_buf[t, :, pl.ds(slot * psz, psz)] = s
            v_buf[:, pl.ds(slot * psz, psz), :] = v.swapaxes(0, 1)

        @pl.when(jnp.logical_not(mapped))
        def _mask_out(slot=slot):
            # no pool read at all: score lanes -inf, V lanes 0 (the dense
            # path zeroes gathered trash-page rows for the same reason)
            s_buf[:, :, pl.ds(slot * psz, psz)] = jnp.full(
                (*s_buf.shape[:2], psz), -jnp.inf, s_buf.dtype)
            v_buf[:, pl.ds(slot * psz, psz), :] = jnp.zeros(
                (v_buf.shape[0], psz, v_buf.shape[2]), v_buf.dtype)

    @pl.when(j == n_steps - 1)
    def _normalize_once():
        s = s_buf[...]                                # (hb, g, S)
        m = jnp.max(s, axis=-1)
        # all-masked rows (slot with zero live entries) keep m = -inf; the
        # guard makes them exp(-inf - 0) = 0 instead of exp(nan)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        for t in range(hb):
            pq = _cast_in(p[t].astype(q_ref.dtype), fmt)
            out = jax.lax.dot_general(
                pq, v_buf[t], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            if approx:
                out = truncate_mantissa(out)
            o_ref[0, t] = out


@functools.partial(
    jax.jit, static_argnames=("window", "cap", "scale", "ppb", "hb", "fmt",
                              "approx", "interpret"))
def _paged_decode(qg, k_pool, v_pool, page_positions, block_table, pos, *,
                  window: int, cap: float, scale: float, ppb: int, hb: int,
                  fmt: str, approx: bool, interpret: bool):
    B, KVH, g, hd = qg.shape
    psz = k_pool.shape[1]
    P = block_table.shape[1]

    def page_idx(i):
        # the prefetched block table drives the pool index: unmapped (-1)
        # entries clamp to the trash page, whose block the kernel never reads
        return lambda b, h, j, bt, ps: (jnp.maximum(bt[b, j * ppb + i], 0),
                                        0, h, 0)

    def pagepos_idx(i):
        return lambda b, h, j, bt, ps: (jnp.maximum(bt[b, j * ppb + i], 0), 0)

    def run(qb, btb, posb):
        bb = qb.shape[0]
        grid = (bb, KVH // hb, P // ppb)
        in_specs = [pl.BlockSpec((1, hb, g, hd),
                                 lambda b, h, j, bt, ps: (b, h, 0, 0))]
        in_specs += [pl.BlockSpec((1, psz, hb, hd), page_idx(i))
                     for i in range(ppb)]
        in_specs += [pl.BlockSpec((1, psz, hb, hd), page_idx(i))
                     for i in range(ppb)]
        in_specs += [pl.BlockSpec((1, psz), pagepos_idx(i))
                     for i in range(ppb)]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, hb, g, hd),
                                   lambda b, h, j, bt, ps: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((hb, g, P * psz), jnp.float32),
                pltpu.VMEM((hb, P * psz, hd), _container_dtype(fmt)),
            ],
        )
        kernel = functools.partial(_decode_kernel, ppb=ppb, hb=hb, psz=psz,
                                   n_steps=grid[2], scale=scale,
                                   window=window, cap=cap, fmt=fmt,
                                   approx=approx)
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((bb, KVH, g, hd), jnp.float32),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(btb.astype(jnp.int32), posb.astype(jnp.int32), qb,
          *([k_pool] * ppb), *([v_pool] * ppb), *([page_positions] * ppb))

    if interpret and B > 1:
        # Interpret-mode lowering runs the grid as an XLA while loop whose
        # carry holds EVERY operand — each step past the first re-writes
        # all 3·ppb pool-sized carries (measured ~3 ms/step at a 4 MB
        # pool), while a single-step grid folds the loop away entirely.
        # The batch axis would force >= B steps, so on CPU we unroll it
        # into B independent single-slot calls instead; each one can then
        # collapse to one grid step when (ppb, hb) = (P, KVH). Numerics
        # are per-(b, h) slices either way — bit-identical. On TPU the
        # batched grid stands: steps are real parallel work there, and
        # the pools are never in any carry.
        return jnp.concatenate(
            [run(qg[b:b + 1], block_table[b:b + 1], pos[b:b + 1])
             for b in range(B)], axis=0)
    return run(qg, block_table, pos)


def sa_paged_decode_attention(q, k_pool, v_pool, page_positions, block_table,
                              pos, *, window: int = 0, cap: float = 0.0,
                              scale: float | None = None,
                              ppb: int | None = None, hb: int | None = None,
                              policy=None, interpret: bool = False):
    """Fused paged decode attention.

    q: (B, 1, H, hd); pools: (n_pages, psz, KVH, hd);
    page_positions: (n_pages, psz) int32 (-1 = empty);
    block_table: (B, max_pages) int32 page ids (-1 = unmapped, 0 = trash);
    pos: (B,) per-slot current position. → (B, 1, H, hd) fp32.

    Bit-identical to ``decode_attention(q, *gather_pages(cache), pos)`` for
    every supported policy (`fused_decode_supported`). `ppb`/`hb` default to
    the autotuned `pages_per_block` / KV-head tiling for this workload
    (`autotune.lookup_decode_attn`); explicit values are clipped to
    divisors, so any (ppb, hb) is safe to pin.
    """
    from repro.core.precision import current_policy
    policy = policy or current_policy()
    if not fused_decode_supported(policy):
        raise ValueError(
            f"fused paged decode does not support input_format="
            f"{policy.input_format!r} / output_format="
            f"{policy.output_format!r}; use the gather path")
    B, _, H, hd = q.shape
    psz, KVH = k_pool.shape[1], k_pool.shape[2]
    P = block_table.shape[1]
    g = H // KVH
    scale = scale or hd ** -0.5
    if ppb is None or hb is None:
        from . import autotune
        tppb, thb = autotune.lookup_decode_attn(B, KVH, g, hd, psz, P)
        ppb, hb = ppb or tppb, hb or thb
    ppb = largest_divisor(P, ppb)
    hb = largest_divisor(KVH, hb)
    out = _paged_decode(q.reshape(B, KVH, g, hd), k_pool, v_pool,
                        page_positions, block_table, pos,
                        window=int(window), cap=float(cap or 0.0),
                        scale=float(scale), ppb=ppb, hb=hb,
                        fmt=policy.input_format,
                        approx=policy.mode == "approx", interpret=interpret)
    return out.reshape(B, 1, H, hd)
