"""Pallas TPU kernel: the SA column's arithmetic contract as an MXU GEMM.

TPU-native restatement of the paper's skewed pipeline (DESIGN.md §2b):

  * the K-grid dimension is the **column of PEs** — each step fuses one
    (bm×bk)·(bk×bn) product into the running block result;
  * the accumulator lives **unnormalized in fp32 VMEM scratch across all K
    steps** — the chain is never rounded/materialized between steps (the
    paper's "no per-PE normalization, double-width reduction");
  * the Pallas grid pipelines the *next* K-tile's HBM→VMEM DMA under the
    *current* tile's MXU work — the software analogue of the skew's
    stage-overlap between consecutive PEs;
  * rounding to the output format happens exactly once, in the final K step
    (the paper's single rounder at the column south end).

Fused epilogue (DESIGN.md §2c): the final K step can apply, *before* the
single rounding, ``y = act(acc · scale + bias)`` — output descale for the
FP8 path, bias add, and a pointwise activation. This keeps the paper's
round-once contract while eliminating the separate elementwise passes the
model layers would otherwise run on the already-rounded output.

The op carries a `jax.custom_vjp`: both backward GEMMs (dA = dY·Wᵀ and
dW = Aᵀ·dY) run through the same round-once kernel, so the pallas backend
works under `jax.grad` (training on the paper's datapath).

Block shapes default to MXU-aligned (multiples of 128 in M/N, 512 in K) and
are swept/cached by `repro.kernels.autotune`.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pltpu_compat import CompilerParams as _CompilerParams

EPILOGUES = ("none", "relu", "gelu", "silu")
MODES = ("exact", "approx")

# The MXU-path model of the approximate-normalization datapath ("bulk"
# serving tier): chained_fma.approx_chain bounds the coarse-LZA truncation
# debt to GUARD bits of the wide accumulator, so on the production fp32
# chain the same information loss is the low GUARD mantissa bits of the
# accumulator — dropped (round-to-zero) before the single output rounding.
APPROX_DROP_BITS = 3


def truncate_mantissa(y: jax.Array, bits: int = APPROX_DROP_BITS) -> jax.Array:
    """Zero the low `bits` mantissa bits of an fp32 array (RTZ truncation).

    Shared by every backend's mode="approx" path (pallas epilogue, xla
    fallback in core/precision.py) so the tier arithmetic is
    backend-independent."""
    b = jax.lax.bitcast_convert_type(y.astype(jnp.float32), jnp.uint32)
    b = b & ~jnp.uint32((1 << bits) - 1)
    return jax.lax.bitcast_convert_type(b, jnp.float32)


def apply_act(y: jax.Array, act: str) -> jax.Array:
    """Pointwise epilogue activation (shared by all backends for parity)."""
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "gelu":
        return jax.nn.gelu(y)
    if act == "silu":
        return jax.nn.silu(y)
    return y


# minimum hardware tile: 16 sublanes (bf16) × 128 lanes. bm is sublane-only;
# bk is a lane dim in the A block AND a sublane dim in the W block, so it
# takes the stricter 128; bn is lane-only.
_SUBLANE, _LANE = 16, 128


def _round_up(d: int, unit: int) -> int:
    return -(-d // unit) * unit


def clip_blocks(bm: int, bn: int, bk: int, m: int, n: int, k: int
                ) -> tuple[int, int, int]:
    """Clip requested block dims to the problem — but never below the
    hardware tile: small/ragged dims clip to the *tile-rounded* size (the
    input is zero-padded to a block multiple anyway), so Mosaic always sees
    (16, 128)-aligned blocks. A caller-pinned block smaller than the tile is
    honored as-is (interpret-mode tests sweep tiny blocks)."""
    return (min(bm, _round_up(m, _SUBLANE)),
            min(bn, _round_up(n, _LANE)),
            min(bk, _round_up(k, _LANE)))


def default_blocks(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Heuristic MXU-aligned block shapes (autotune's fallback)."""
    return clip_blocks(256, 256, 512, m, n, k)


def _matmul_kernel(a_ref, w_ref, scale_ref, *refs, n_k: int, out_dtype,
                   act: str, has_bias: bool, save_raw: bool, approx: bool):
    """One (i, j, k) grid step: psum_k = psum_{k-1} + A_ik · W_kj."""
    if has_bias:
        bias_ref, refs = refs[0], refs[1:]
    o_ref = refs[0]
    raw_ref = refs[1] if save_raw else None
    acc_ref = refs[-1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The chained multiply-add: MXU product accumulated into the persistent
    # fp32 scratch (never normalized/rounded mid-chain).
    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue_and_round_once():
        # epilogue on the unnormalized fp32 chain, then the single rounding
        # at the end of the K chain (column south end)
        raw = acc_ref[...]
        if approx:
            # bulk-tier arithmetic: drop the accumulator's guard-band low
            # bits (the information a coarse-LZA datapath loses) before the
            # epilogue and the single rounding
            raw = truncate_mantissa(raw)
        if save_raw:
            raw_ref[...] = raw
        y = raw * scale_ref[0, 0]
        if has_bias:
            y = y + bias_ref[...].astype(jnp.float32)   # (1, bn) broadcast
        y = apply_act(y, act)
        o_ref[...] = y.astype(out_dtype)


def _pallas_fused(a, w, bias, scale, *, act, bm, bn, bk, out_dtype,
                  save_raw, interpret, mode="exact"):
    """pallas_call plumbing: padding, specs, optional raw-accumulator output."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    bm, bn, bk = clip_blocks(bm, bn, bk, m, n, k)
    # pad to block multiples (zero products are exact under the contract)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        # scalar epilogue scale: (1, 1) in SMEM (Mosaic cannot deref ANY)
        pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0),
                     memory_space=pltpu.SMEM),
    ]
    operands = [a, w, jnp.asarray(scale, jnp.float32).reshape(1, 1)]
    if bias is not None:
        if pn:
            bias = jnp.pad(bias, ((0, pn),))
        # 2-D (1, bn) block: 1-D blocks don't tile cleanly on Mosaic lanes
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        operands.append(bias.reshape(1, -1))

    out_block = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out_shape = [jax.ShapeDtypeStruct((m + pm, n + pn), out_dtype)]
    out_specs = [out_block]
    if save_raw:
        out_shape.append(jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32))
        out_specs.append(out_block)

    kernel = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2], out_dtype=out_dtype,
                          act=act, has_bias=bias is not None,
                          save_raw=save_raw, approx=(mode == "approx")),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    outs = kernel(*operands)
    if pm or pn:
        outs = [o[:m, :n] for o in outs]
    return outs if save_raw else outs[0]


@dataclasses.dataclass(frozen=True)
class _GemmCfg:
    """Static configuration of one fused GEMM (nondiff arg of the vjp)."""
    act: str
    bm: int
    bn: int
    bk: int
    out_dtype: object
    interpret: bool
    has_scale: bool = False   # caller passed a real scale (vs synthesized 1)
    mode: str = "exact"       # "approx" = bulk-tier truncated accumulator

    @property
    def needs_raw(self) -> bool:
        # the backward pass needs the unnormalized accumulator only for the
        # activation jacobian or a real dscale; plain GEMMs (the majority of
        # training projections) skip the second (M, N) fp32 output entirely
        return self.act != "none" or self.has_scale


def _bwd_blocks(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Block shapes for the backward GEMMs: autotune cache else heuristic.

    The import is function-level because autotune imports this module at
    load time (it times the kernel); by backward-execution time it is
    always importable."""
    from .autotune import lookup
    return lookup(m, n, k, dtype="float32", epilogue="none", sweep=False)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sa_matmul_vjp(cfg: _GemmCfg, a, w, bias, scale):
    return _pallas_fused(a, w, bias, scale, act=cfg.act, bm=cfg.bm, bn=cfg.bn,
                         bk=cfg.bk, out_dtype=cfg.out_dtype, save_raw=False,
                         interpret=cfg.interpret, mode=cfg.mode)


def _sa_matmul_fwd(cfg: _GemmCfg, a, w, bias, scale):
    # when the epilogue is nontrivial, the kernel emits the unnormalized
    # fp32 accumulator alongside the epilogued output, so the backward pass
    # can form the activation jacobian / dscale without a recompute GEMM
    out = _pallas_fused(a, w, bias, scale, act=cfg.act, bm=cfg.bm,
                        bn=cfg.bn, bk=cfg.bk, out_dtype=cfg.out_dtype,
                        save_raw=cfg.needs_raw, interpret=cfg.interpret,
                        mode=cfg.mode)
    y, raw = out if cfg.needs_raw else (out, None)
    return y, (a, w, bias, scale, raw)


def _sa_matmul_bwd(cfg: _GemmCfg, res, dy):
    a, w, bias, scale, raw = res
    dy = dy.astype(jnp.float32)
    scale32 = jnp.asarray(scale, jnp.float32)
    if raw is None:       # act == "none" and scale synthesized: linear vjp
        du = dy
        dscale = jnp.zeros((), scale.dtype)
    else:
        u = raw * scale32
        if bias is not None:
            u = u + bias.astype(jnp.float32)
        if cfg.act == "none":
            du = dy
        else:
            _, act_vjp = jax.vjp(lambda t: apply_act(t, cfg.act), u)
            (du,) = act_vjp(dy)
        dscale = jnp.sum(du * raw).astype(scale.dtype)
    dbias = jnp.sum(du, axis=0).astype(bias.dtype) if bias is not None else None
    dus = du * scale32
    # both backward GEMMs run through the same round-once kernel (fp32
    # operands: every reduced-format value is exact in fp32, so upcasting
    # the saved a/w changes nothing)
    one = jnp.float32(1.0)
    m, k = a.shape
    n = w.shape[1]
    da_b = _bwd_blocks(m, k, n)
    da = _pallas_fused(dus, w.astype(jnp.float32).T, None, one, act="none",
                       bm=da_b[0], bn=da_b[1], bk=da_b[2],
                       out_dtype=jnp.float32, save_raw=False,
                       interpret=cfg.interpret)
    dw_b = _bwd_blocks(k, n, m)
    dw = _pallas_fused(a.astype(jnp.float32).T, dus, None, one, act="none",
                       bm=dw_b[0], bn=dw_b[1], bk=dw_b[2],
                       out_dtype=jnp.float32, save_raw=False,
                       interpret=cfg.interpret)
    return da.astype(a.dtype), dw.astype(w.dtype), dbias, dscale


_sa_matmul_vjp.defvjp(_sa_matmul_fwd, _sa_matmul_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("act", "bm", "bn", "bk", "out_dtype", "interpret",
                     "mode"))
def sa_matmul_pallas(a: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                     scale: jax.Array | float | None = None, *,
                     act: str = "none", bm: int = 256, bn: int = 256,
                     bk: int = 512, out_dtype=jnp.float32,
                     interpret: bool = False, mode: str = "exact"):
    """(M, K) @ (K, N) with SA-contract arithmetic. Inputs bf16 (or fp8
    values carried in bf16/f32 containers); fused epilogue
    ``act(acc·scale + bias)`` applied before the single rounding to
    `out_dtype`. Differentiable (custom VJP; backward GEMMs use the same
    kernel).

    ``mode="approx"`` is the bulk serving tier: the accumulator's low
    APPROX_DROP_BITS mantissa bits are truncated before the epilogue and
    the single rounding (forward only — backward GEMMs stay exact)."""
    if act not in EPILOGUES:
        raise ValueError(f"unknown epilogue act {act!r}; have {EPILOGUES}")
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; have {MODES}")
    if bias is not None and bias.ndim != 1:
        # the kernel's (1, bn) block broadcasts a single bias row per output
        # column tile — anything but a (N,) vector would be silently wrong
        raise ValueError(f"bias must be a (N,) vector, got {bias.shape}")
    scale_arr = jnp.asarray(1.0 if scale is None else scale, jnp.float32)
    cfg = _GemmCfg(act=act, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
                   interpret=interpret, has_scale=scale is not None,
                   mode=mode)
    return _sa_matmul_vjp(cfg, a, w, bias, scale_arr)
