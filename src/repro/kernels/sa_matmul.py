"""Pallas TPU kernel: the SA column's arithmetic contract as an MXU GEMM.

TPU-native restatement of the paper's skewed pipeline (DESIGN.md §2b):

  * the K-grid dimension is the **column of PEs** — each step fuses one
    (bm×bk)·(bk×bn) product into the running block result;
  * the accumulator lives **unnormalized in fp32 VMEM scratch across all K
    steps** — the chain is never rounded/materialized between steps (the
    paper's "no per-PE normalization, double-width reduction");
  * the Pallas grid pipelines the *next* K-tile's HBM→VMEM DMA under the
    *current* tile's MXU work — the software analogue of the skew's
    stage-overlap between consecutive PEs;
  * rounding to the output format happens exactly once, in the final K step
    (the paper's single rounder at the column south end).

Block shapes default to MXU-aligned (multiples of 128 in M/N, 512 in K) and
are swept by `benchmarks/kernel_bench.py`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, w_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    """One (i, j, k) grid step: psum_k = psum_{k-1} + A_ik · W_kj."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # The chained multiply-add: MXU product accumulated into the persistent
    # fp32 scratch (never normalized/rounded mid-chain).
    acc_ref[...] += jnp.dot(a_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _round_once():
        # single rounding at the end of the K chain (column south end)
        o_ref[...] = acc_ref[...].astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"))
def sa_matmul_pallas(a: jax.Array, w: jax.Array, *, bm: int = 256,
                     bn: int = 256, bk: int = 512,
                     out_dtype=jnp.float32, interpret: bool = False):
    """(M, K) @ (K, N) with SA-contract arithmetic. Inputs bf16 (or fp8
    values carried in bf16); output rounded once to `out_dtype`."""
    m, k = a.shape
    k2, n = w.shape
    assert k == k2, (a.shape, w.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # pad to block multiples (zero products are exact under the contract)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    kernel = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )
    out = kernel(a, w)
    return out[:m, :n] if (pm or pn) else out
