"""Block-shape autotuner for the SA GEMM (ArrayFlex-style configurability).

Sweeps (bm, bn, bk) per (M, N, K, dtype, epilogue) workload and remembers the
winner in two layers:

  * an **in-process dict** (`_MEM`) consulted on every `lookup`, and
  * an **on-disk JSON cache** so tuning results persist across processes
    (default ``~/.cache/repro_sa/autotune.json``; override with
    ``REPRO_AUTOTUNE_CACHE``).

Entries are keyed by backend (``cpu-interpret`` on this container, ``tpu``
on hardware) — interpret-mode timings never pollute hardware decisions.

`lookup` is the cheap path used by `repro.kernels.ops.sa_matmul` on every
call: memory cache → disk cache → MXU-aligned heuristic. It only *sweeps*
when asked (``sweep=True`` or ``REPRO_AUTOTUNE=1``), so test/serving paths
never pay tuning latency by surprise. A corrupt or unreadable cache file is
ignored, never fatal.

Cache format (DESIGN.md §2d)::

    {"version": 1,
     "entries": {"cpu-interpret|256x256x512|bfloat16|none":
                 {"blocks": [256, 256, 512], "us": 812.4}}}
"""
from __future__ import annotations

import contextlib
import json
import os
import time

try:
    import fcntl
except ImportError:  # pragma: no cover - non-posix
    fcntl = None

import jax
import jax.numpy as jnp
import numpy as np

from .sa_matmul import clip_blocks, default_blocks, sa_matmul_pallas

_VERSION = 1
# entry values are block tuples: (bm, bn, bk) for GEMM keys, (ppb, hb) for
# the paged decode-attention keys ("|dattn|" — pages_per_block, head tiling)
_MEM: dict[str, tuple[int, ...]] = {}
_DISK_LOADED = False

# candidate (bm, bn, bk) shapes; clipped to the problem and deduped per
# shape. All tile-aligned by construction (bm % 16, bn/bk % 128 == 0), so
# the tile-rounded clip in candidates_for keeps every swept shape aligned.
CANDIDATES = (
    (64, 128, 128),
    (128, 128, 256),
    (128, 256, 512),
    (256, 128, 512),
    (256, 256, 512),
    (512, 256, 512),
    (256, 512, 1024),
)

# decode / GEMV shapes: per-token serving GEMMs have M = B·T ∈ {1..16}
# (clip_blocks rounds any smaller M up to one 16-sublane tile), so bm
# collapses and the sweep is really over the (bn, bk) tiling — which is
# what differentiates latency when the whole M side fits in one tile pass
# and the K-chain (the SA column) dominates. Only swept when M fits one
# candidate block (m <= bm): at training M these shapes are never
# competitive and would just add compiles to every sweep.
DECODE_CANDIDATES = (
    (16, 128, 512),
    (16, 256, 1024),
    (16, 512, 512),
    (32, 256, 512),
)


def backend_key() -> str:
    """Cache namespace: platform, plus '-interpret' off-TPU (interpret-mode
    timings must never steer hardware block choices)."""
    plat = jax.default_backend()
    return plat if plat == "tpu" else f"{plat}-interpret"


def production_dtype() -> str:
    """The dtype `sa_dot` actually hands the kernel on this backend: f32
    containers on CPU (`precision.EXACT_CPU_CONTAINERS`), bf16 on TPU.
    Sweeps (bench / pre-seeders) must tune under this dtype — entries swept
    under any other are cache keys the production path never reads."""
    from repro.core.precision import EXACT_CPU_CONTAINERS
    return "float32" if EXACT_CPU_CONTAINERS else "bfloat16"


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro_sa",
                     "autotune.json"))


def _key(m: int, n: int, k: int, dtype: str, epilogue: str) -> str:
    return f"{backend_key()}|{m}x{n}x{k}|{dtype}|{epilogue}"


def _read_disk() -> dict:
    """Parse the on-disk cache; corrupt/missing files are just empty."""
    try:
        with open(cache_path()) as f:
            data = json.load(f)
        entries = data.get("entries", {})
        if data.get("version") != _VERSION or not isinstance(entries, dict):
            return {}
        return entries
    except (OSError, ValueError):
        return {}


def _load_disk_once():
    global _DISK_LOADED
    if _DISK_LOADED:
        return
    _DISK_LOADED = True
    for key, ent in _read_disk().items():
        try:
            blocks = tuple(int(x) for x in ent["blocks"])
        except (KeyError, TypeError, ValueError):
            continue
        if blocks:
            _MEM.setdefault(key, blocks)


@contextlib.contextmanager
def _file_lock(path: str):
    """flock-serialized critical section so concurrent tuners don't drop
    each other's entries in the read-merge-write below (best-effort: no-op
    where flock is unavailable)."""
    if fcntl is None:
        yield
        return
    with open(f"{path}.lock", "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


def _write_disk(key: str, blocks: tuple[int, int, int], us: float):
    """Merge one entry into the JSON cache (flock + tmp-rename atomic)."""
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with _file_lock(path):
            entries = _read_disk()
            entries[key] = {"blocks": list(blocks), "us": round(float(us), 2)}
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump({"version": _VERSION, "entries": entries}, f,
                          indent=1)
            os.replace(tmp, path)
    except OSError:
        pass  # read-only FS etc. — in-process cache still works


def reset():
    """Forget the in-process cache (tests: simulates a fresh process)."""
    global _DISK_LOADED
    _MEM.clear()
    _DISK_LOADED = False


def candidates_for(m: int, n: int, k: int) -> list[tuple[int, int, int]]:
    decode = tuple(c for c in DECODE_CANDIDATES if m <= c[0])
    seen, out = set(), []
    for bm, bn, bk in CANDIDATES + decode + (default_blocks(m, n, k),):
        # same tile-aligned clipping the kernel applies, so cached entries
        # record the blocks that actually run
        c = clip_blocks(bm, bn, bk, m, n, k)
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def _time_blocks(m, n, k, dtype, epilogue, blocks, reps=3) -> float:
    rng = np.random.default_rng(0)
    dt = jnp.dtype(dtype)
    a = jnp.asarray(rng.standard_normal((m, k)), dt)
    w = jnp.asarray(rng.standard_normal((k, n)), dt)
    bias = jnp.zeros((n,), jnp.float32) if epilogue != "none" else None
    interpret = jax.default_backend() != "tpu"
    bm, bn, bk = blocks

    def run():
        return sa_matmul_pallas(a, w, bias, act=epilogue, bm=bm, bn=bn,
                                bk=bk, interpret=interpret)

    run().block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = run()
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def tune(m: int, n: int, k: int, *, dtype: str = "bfloat16",
         epilogue: str = "none", reps: int = 3
         ) -> tuple[tuple[int, int, int], list[dict]]:
    """Sweep candidate block shapes; cache and return the winner.

    Returns (best_blocks, table) where table rows are
    {"blocks": (bm,bn,bk), "us": float} sorted by time.
    """
    table = [{"blocks": c, "us": _time_blocks(m, n, k, dtype, epilogue, c,
                                              reps=reps)}
             for c in candidates_for(m, n, k)]
    table.sort(key=lambda r: r["us"])
    best = tuple(table[0]["blocks"])
    key = _key(m, n, k, dtype, epilogue)
    _MEM[key] = best
    _write_disk(key, best, table[0]["us"])
    return best, table


def _trace_state_clean() -> bool:
    """True when no jit trace is in flight (a sweep must execute eagerly).
    jax >= 0.6 drops `trace_state_clean` from the public `jax.core`."""
    try:
        return jax.core.trace_state_clean()
    except AttributeError:     # pragma: no cover - newer jax
        from jax._src.core import trace_state_clean
        return trace_state_clean()


def tune_decode(n: int, k: int, ms: tuple[int, ...] = (1, 4, 8), *,
                dtype: str = "bfloat16", reps: int = 3
                ) -> dict[int, tuple[int, int, int]]:
    """Pre-seed the cache with decode-shape winners: M ∈ `ms` GEMVs against
    one (K, N) weight. Serving engines can call this once at startup so the
    jitted decode step gets tuned blocks (lookup cannot sweep mid-trace)."""
    return {m: tune(m, n, k, dtype=dtype, reps=reps)[0] for m in ms}


def tune_spec_verify(n: int, k: int, batch: int, spec_k: int, *,
                     dtype: str = "bfloat16", reps: int = 3
                     ) -> dict[int, tuple[int, int, int]]:
    """Pre-seed the speculative-decode GEMM shapes (DESIGN.md §9): the
    draft/plain decode rows at M = batch and the batched verify forward at
    M = batch·(spec_k+1) — the verify folds each slot's k+1 draft rows
    into the batch axis, so its GEMMs run at that one M. Same startup
    contract as `tune_decode` (lookup cannot sweep inside the jitted spec
    chunk)."""
    return tune_decode(n, k, ms=(batch, batch * (spec_k + 1)),
                       dtype=dtype, reps=reps)


def lookup(m: int, n: int, k: int, *, dtype: str = "bfloat16",
           epilogue: str = "none", sweep: bool | None = None
           ) -> tuple[int, int, int]:
    """Best-known (bm, bn, bk): memory → disk → (optional sweep) → heuristic.

    `sweep=None` defers to the ``REPRO_AUTOTUNE`` env var (default off), so
    production callers hit at most one JSON read per process. A sweep
    cannot run while an outer `jit` is tracing (the timing calls would
    trace into the caller's computation instead of executing), so mid-trace
    misses fall back to the heuristic — pre-seed the cache eagerly
    (`tune()` / `benchmarks/kernel_bench.py`) to get tuned blocks inside
    jitted steps.

    A miss on an epilogue-specific key falls back to the bare-GEMM entry
    for the same shape: the epilogue is O(M·N) elementwise against the
    O(M·N·K) GEMM, so tuned blocks transfer — and the fused-activation FFN
    paths benefit from a cache swept with ``epilogue="none"``.
    """
    _load_disk_once()
    key = _key(m, n, k, dtype, epilogue)
    hit = _MEM.get(key)
    if hit is None and epilogue != "none":
        hit = _MEM.get(_key(m, n, k, dtype, "none"))
    if hit is not None:
        return hit
    if sweep is None:
        sweep = os.environ.get("REPRO_AUTOTUNE", "0") not in ("0", "false",
                                                              "off")
    if sweep and _trace_state_clean():
        return tune(m, n, k, dtype=dtype, epilogue=epilogue)[0]
    # heuristic fallback — deliberately NOT memoized, so a later in-process
    # sweep can still take over this key (the disk cache is only read once
    # per process, so cross-process updates need a restart to be seen)
    return default_blocks(m, n, k)


# ---------------------------------------------------------------------------
# Paged decode-attention grid shapes (kernels/sa_decode_attention.py)
# ---------------------------------------------------------------------------

# (pages_per_block, kv_heads_per_block) candidates; clipped to divisors of
# (max_pages, KVH) per workload and deduped. More pages per grid step
# amortizes per-step overhead; head tiling trades grid steps for VMEM.
DECODE_ATTN_CANDIDATES = (
    (1, 1),
    (2, 1),
    (4, 1),
    (8, 1),
    (2, 2),
    (4, 2),
)


def decode_attn_key(batch: int, kvh: int, g: int, hd: int, psz: int,
                    max_pages: int, dtype: str) -> str:
    return (f"{backend_key()}|dattn|{batch}x{kvh}x{g}x{hd}|"
            f"{psz}x{max_pages}|{dtype}")


def default_decode_attn_blocks(kvh: int, max_pages: int) -> tuple[int, int]:
    """Heuristic: walk up to 8 pages per grid step, one KV head."""
    from .sa_decode_attention import largest_divisor
    return largest_divisor(max_pages, 8), 1


def decode_attn_candidates(kvh: int, max_pages: int
                           ) -> list[tuple[int, int]]:
    from .sa_decode_attention import largest_divisor
    # (max_pages, kvh) collapses the page/head axes into a single grid
    # step — the interpret-mode winner (no while-loop carry copies) and a
    # legitimate TPU shape for small pools
    pool = DECODE_ATTN_CANDIDATES + (
        (max_pages, 1), (max_pages, kvh),
        default_decode_attn_blocks(kvh, max_pages))
    seen, out = set(), []
    for ppb, hb in pool:
        c = (largest_divisor(max_pages, ppb), largest_divisor(kvh, hb))
        if c not in seen:
            seen.add(c)
            out.append(c)
    return out


def tune_decode_attn(batch: int, kvh: int, g: int, hd: int, psz: int,
                     max_pages: int, *, dtype: str | None = None,
                     mapped_pages: int | None = None, reps: int = 2
                     ) -> tuple[tuple[int, int], list[dict]]:
    """Sweep (pages_per_block, head tiling) for one paged decode-attention
    workload; cache and return the winner, `tune()`-style.

    Timed on a synthetic pool with `mapped_pages` pages mapped per slot
    (default: half the block table — the mid-sparsity regime serving
    actually sits in). Serving engines call this once at startup
    (`launch/serve.py --autotune-decode`): the jitted decode chunk cannot
    sweep mid-trace, so winners must be on disk/in memory before the first
    chunk compiles.
    """
    from .sa_decode_attention import sa_paged_decode_attention
    dtype = dtype or production_dtype()
    mapped = mapped_pages or max(1, max_pages // 2)
    mapped = min(mapped, max_pages)
    rng = np.random.default_rng(0)
    n_pages = batch * max_pages + 1
    dt = jnp.dtype(dtype)
    k_pool = jnp.asarray(rng.standard_normal((n_pages, psz, kvh, hd)), dt)
    v_pool = jnp.asarray(rng.standard_normal((n_pages, psz, kvh, hd)), dt)
    bt = np.full((batch, max_pages), -1, np.int32)
    for b in range(batch):
        bt[b, :mapped] = 1 + b * max_pages + np.arange(mapped)
    page_pos = np.full((n_pages, psz), -1, np.int32)
    for b in range(batch):
        page_pos[bt[b, :mapped].reshape(-1)] = np.arange(
            mapped * psz, dtype=np.int32).reshape(mapped, psz)
    bt, page_pos = jnp.asarray(bt), jnp.asarray(page_pos)
    pos = jnp.full((batch,), mapped * psz - 1, jnp.int32)
    q = jnp.asarray(rng.standard_normal((batch, 1, kvh * g, hd)), dt)
    interpret = jax.default_backend() != "tpu"

    def time_one(ppb, hb):
        def run():
            return sa_paged_decode_attention(
                q, k_pool, v_pool, page_pos, bt, pos, ppb=ppb, hb=hb,
                interpret=interpret)
        run().block_until_ready()      # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = run()
        out.block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    table = [{"blocks": c, "us": time_one(*c)}
             for c in decode_attn_candidates(kvh, max_pages)]
    table.sort(key=lambda r: r["us"])
    best = tuple(table[0]["blocks"])
    key = decode_attn_key(batch, kvh, g, hd, psz, max_pages, dtype)
    _MEM[key] = best
    _write_disk(key, best, table[0]["us"])
    return best, table


def lookup_decode_attn(batch: int, kvh: int, g: int, hd: int, psz: int,
                       max_pages: int, *, dtype: str | None = None,
                       sweep: bool | None = None) -> tuple[int, int]:
    """Best-known (pages_per_block, head tiling): memory → disk →
    (optional sweep) → heuristic. Same contract as `lookup`: consulted at
    trace time by `sa_paged_decode_attention`, never sweeps mid-trace."""
    _load_disk_once()
    dtype = dtype or production_dtype()
    hit = _MEM.get(decode_attn_key(batch, kvh, g, hd, psz, max_pages, dtype))
    if hit is not None and len(hit) == 2:
        return hit
    if sweep is None:
        sweep = os.environ.get("REPRO_AUTOTUNE", "0") not in ("0", "false",
                                                              "off")
    if sweep and _trace_state_clean():
        return tune_decode_attn(batch, kvh, g, hd, psz, max_pages,
                                dtype=dtype)[0]
    return default_decode_attn_blocks(kvh, max_pages)
