"""Data pipeline: host-sharded token streams with background prefetch.

Two sources:
  * `SyntheticLM` — deterministic per-(step, host) seeded token batches;
    used by the examples, benchmarks and the multi-pod dry-run (no dataset
    gate: repro band expects a laptop-scale pure-algorithm build).
  * `MemmapTokens` — flat binary token file (np.memmap), strided across
    hosts; the production path for real corpora.

Both yield global-batch-per-host slices: on a real multi-host pod each
process feeds its addressable shard (`jax.process_index()`); the elastic
restart path re-slices by the *current* host count, so a shrunk/grown job
keeps a consistent global batch (fault tolerance, DESIGN.md §4).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token data (shifted-sequence labels)."""

    def __init__(self, vocab_size: int, seq_len: int, batch_per_host: int,
                 seed: int = 0, structured: bool = False):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_per_host
        self.seed = seed
        self.structured = structured

    def batch_at(self, step: int, host: int = 0) -> dict:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4099 + host)
        if self.structured:
            # learnable sequences: t_{i+1} = (t_i + stride) mod V with a
            # small stride alphabet — loss visibly drops below log(V)
            start = rng.integers(0, self.vocab, (self.batch, 1))
            stride = rng.choice([1, 2, 3, 5, 7], (self.batch, 1))
            idx = np.arange(self.seq + 1)[None]
            toks = ((start + stride * idx) % self.vocab).astype(np.int32)
        else:
            toks = rng.integers(0, self.vocab,
                                size=(self.batch, self.seq + 1),
                                dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        host = jax.process_index()
        step = 0
        while True:
            yield self.batch_at(step, host)
            step += 1


class MemmapTokens:
    """Flat int32 token file; contiguous windows strided over hosts."""

    def __init__(self, path: str, seq_len: int, batch_per_host: int,
                 n_hosts: int | None = None, host: int | None = None):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq_len
        self.batch = batch_per_host
        self.n_hosts = n_hosts if n_hosts is not None else jax.process_count()
        self.host = host if host is not None else jax.process_index()
        self.n_windows = (len(self.data) - 1) // seq_len

    def batch_at(self, step: int) -> dict:
        idx = (step * self.n_hosts + self.host) * self.batch
        rows = [(idx + i) % self.n_windows for i in range(self.batch)]
        toks = np.stack([self.data[r * self.seq:(r + 1) * self.seq + 1]
                         for r in rows]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch: overlaps host data prep with device step."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self._stop.is_set():
                    return
                self.q.put(item)
        finally:
            self.q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass
