"""Continuous-batching serving engine: jitted prefill + chunked decode.

Architecture (DESIGN.md §Serving):

* **Slot table** — batch row == slot. The host-side `SlotScheduler`
  (serve/scheduler.py) admits queued requests into free slots and retires
  finished ones between jitted decode chunks, so the batch never blocks on
  its slowest member (the old engine's static batch did).
* **Per-slot positions** — the decode step takes a (B,) position vector;
  each KV cache row keys/masks on its own per-slot positions
  (models/layers.py), so sequences at different depths coexist in one
  decode GEMM batch. M = batch rows per GEMM is exactly the small-M
  latency regime the SA skewed pipeline targets.
* **Batched host syncs** — decode runs `sync_every` steps device-side in a
  single `lax.scan` before the one tokens fetch + scheduler tick per
  chunk; no per-token `bool(done.all())` blocking the dispatch queue.
* **Single-slot prefill** — an admission prefills (1, T_prompt) and the
  resulting cache fragment is dynamic-update-sliced into batch row `slot`
  of every cache leaf (they all carry batch at axis 1 — see
  model.init_cache). Prefill retraces per distinct prompt length; drivers
  should quantize prompt lengths to a small set. Right-padding prompts
  instead would corrupt SSM/hybrid states (padded tokens update the
  recurrence), so exact-length prefill is the correctness-first default.
"""
from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.train.step import make_prefill_step, make_serve_step
from .scheduler import SlotScheduler


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, batch: int,
                 cache_len: int, eos_id: int = 2, cache_dtype=jnp.float32,
                 sync_every: int = 8):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.cache_dtype = cache_dtype
        self.sync_every = max(1, int(sync_every))
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._serve_step = make_serve_step(cfg)
        self._chunks: dict[tuple[int, bool], Any] = {}
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self.last_stats: dict[str, float] = {}

    def new_cache(self, batch: int | None = None):
        return M.init_cache(self.cfg, batch or self.batch, self.cache_len,
                            dtype=self.cache_dtype)

    # ------------------------------------------------------------------
    # jitted building blocks
    # ------------------------------------------------------------------

    @staticmethod
    def _insert_impl(cache, frag, slot):
        """Splice a batch-1 cache fragment into batch row `slot`.

        Every cache leaf carries batch at axis 1 (model.init_cache), so one
        tree-wide dynamic-update-slice replaces the slot's KV rows, per-slot
        positions, and SSM/conv state in a single donated dispatch."""
        return jax.tree.map(
            lambda full, one: lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1), cache, frag)

    def _chunk_fn(self, steps: int, greedy: bool):
        """steps decode iterations in one device-side lax.scan.

        Returns (tok, cache, pos, rng, toks (steps, B)); the caller fetches
        `toks` once per chunk — the only host sync on the decode path."""
        key = (steps, greedy)
        if key not in self._chunks:
            serve_step = self._serve_step

            def chunk(params, tok, cache, pos, frontend, rng):
                def body(carry, _):
                    tok, cache, pos, rng = carry
                    logits, cache = serve_step(params, tok[:, None], cache,
                                               pos, frontend)
                    if greedy:
                        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                    else:
                        rng, k = jax.random.split(rng)
                        nxt = jax.random.categorical(
                            k, logits[:, -1]).astype(jnp.int32)
                    return (nxt, cache, pos + 1, rng), nxt

                (tok, cache, pos, rng), toks = lax.scan(
                    body, (tok, cache, pos, rng), length=steps)
                return tok, cache, pos, rng, toks

            self._chunks[key] = jax.jit(chunk, donate_argnums=(2,))
        return self._chunks[key]

    # ------------------------------------------------------------------
    # static-batch generation (convenience / frontend archs)
    # ------------------------------------------------------------------

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 frontend=None, greedy: bool = True, rng=None):
        """prompts: (B, T_prompt) int32 → (B, ≤max_new_tokens) int32.

        Static batch: all B sequences prefill together and decode in
        lock-step. Decode runs in device-side chunks of `sync_every` steps;
        EOS is checked once per chunk on the fetched token block (the old
        per-token `bool(done.all())` blocked the dispatch queue every
        step), so an early-finishing batch stops at chunk granularity.
        `last_stats` records the prefill/decode wall split."""
        B, T = prompts.shape
        assert B == self.batch
        rng = rng if rng is not None else jax.random.key(0)
        t0 = time.monotonic()
        cache = self.new_cache()
        logits, cache = self._prefill(self.params, prompts, cache, frontend)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        first = np.asarray(tok)              # sync: prefill boundary (TTFT)
        t_prefill = time.monotonic() - t0
        pos = jnp.full((B,), T, jnp.int32)
        cols = [first]
        done = first == self.eos_id
        while len(cols) < max_new_tokens and not done.all():
            steps = min(self.sync_every, max_new_tokens - len(cols))
            tok, cache, pos, rng, toks = self._chunk_fn(steps, greedy)(
                self.params, tok, cache, pos, frontend, rng)
            t_np = np.asarray(toks)          # one sync per chunk
            cols.extend(t_np)
            done |= (t_np == self.eos_id).any(axis=0)
        self.last_stats = {"prefill_s": t_prefill,
                           "decode_s": time.monotonic() - t0 - t_prefill,
                           "decode_tokens": (len(cols) - 1) * B}
        return jnp.asarray(np.stack(cols, axis=1))

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------

    def serve(self, scheduler: SlotScheduler, greedy: bool = True, rng=None,
              clock=time.monotonic) -> dict:
        """Run the continuous-batching loop until the scheduler drains.

        Per-request results/metrics live on the `Request` objects
        (`scheduler.finished`); returns `scheduler.summary()` merged with
        the engine's prefill/decode wall-time split. Text-only for now:
        per-slot frontends would need fragment caches of their own.
        """
        assert scheduler.n_slots == self.batch, \
            (scheduler.n_slots, self.batch)
        if self.cfg.family == "vlm" or self.cfg.is_encdec:
            # prefill/decode below run frontend=None: a vlm/enc-dec arch
            # would silently skip its encoder and generate garbage
            raise ValueError(
                "continuous serving is text-only (per-slot frontends are a "
                "ROADMAP item); use ServeEngine.generate for frontend archs")
        B = self.batch
        rng = rng if rng is not None else jax.random.key(0)
        t0 = clock()
        skew = 0.0          # engine-time fast-forward for frozen clocks

        def now():
            return clock() - t0 + skew
        cache = self.new_cache()
        tok = jnp.zeros((B,), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        prefill_s = decode_s = 0.0

        while not scheduler.drained():
            for slot in scheduler.free_slots():
                req = scheduler.admit(slot, now())
                if req is None:
                    break
                if (self.cfg.family != "ssm"
                        and req.prompt_len + req.max_new_tokens
                        > self.cache_len):
                    # a global-attention KV ring must never wrap: the write
                    # would overwrite live prompt keys and silently corrupt
                    # the request (local windows and SSM state are the only
                    # wrap-safe caches). Retire it as rejected — in-flight
                    # slots keep decoding.
                    scheduler.reject(slot, now())
                    continue
                t_p = now()
                frag = self.new_cache(batch=1)
                logits, frag = self._prefill(
                    self.params, jnp.asarray(req.prompt, jnp.int32)[None],
                    frag, None)
                if greedy:
                    first = int(np.asarray(jnp.argmax(logits[0, -1])))
                else:
                    rng, k = jax.random.split(rng)
                    first = int(np.asarray(
                        jax.random.categorical(k, logits[0, -1])))
                cache = self._insert(cache, frag, slot)
                tok = tok.at[slot].set(first)
                pos = pos.at[slot].set(req.prompt_len)
                dt = now() - t_p
                prefill_s += dt
                scheduler.start(slot, first, now(), prefill_s=dt)

            if scheduler.num_active() == 0:
                # queue non-empty but nothing has arrived yet: wait for the
                # next arrival instead of spinning
                nxt = scheduler.next_arrival()
                if nxt is None:
                    break
                wait = nxt - now()
                if wait > 0:
                    before = clock()
                    time.sleep(min(wait, 0.05))
                    if clock() == before:
                        # injected/frozen clock: real sleeps cannot advance
                        # it — fast-forward engine time to the arrival
                        skew += wait
                continue

            t_d = now()
            tok, cache, pos, rng, toks = self._chunk_fn(
                self.sync_every, greedy)(self.params, tok, cache, pos,
                                         None, rng)
            toks_np = np.asarray(toks)       # the chunk's single host sync
            decode_s += now() - t_d
            scheduler.observe(toks_np, now())

        summary = scheduler.summary()
        summary |= {"prefill_s": round(prefill_s, 4),
                    "decode_s": round(decode_s, 4),
                    "wall_s": round(now(), 4)}
        served = summary["requests"] - summary["rejected"]
        if decode_s > 0 and served:
            # each *served* request's first token came from prefill, not
            # the decode chunks (rejected ones produced nothing at all)
            decode_tokens = summary["generated_tokens"] - served
            summary["decode_tok_s"] = round(decode_tokens / decode_s, 2)
        self.last_stats = summary
        return summary
