"""Batched serving engine: jitted prefill + decode with KV/SSM caches.

Static-batch continuous serving: slots hold independent sequences; finished
slots are refilled by the driver (`launch/serve.py`). Decode is one jitted
step per token over the whole batch — the `decode_*` dry-run cells lower
exactly this function.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.train.step import make_prefill_step, make_serve_step


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, batch: int,
                 cache_len: int, eos_id: int = 2, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(make_serve_step(cfg))

    def new_cache(self):
        return M.init_cache(self.cfg, self.batch, self.cache_len,
                            dtype=self.cache_dtype)

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 frontend=None, greedy: bool = True, rng=None):
        """prompts: (B, T_prompt) int32 → (B, max_new_tokens) int32."""
        B, T = prompts.shape
        assert B == self.batch
        cache = self.new_cache()
        logits, cache = self._prefill(self.params, prompts, cache, frontend)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        rng = rng if rng is not None else jax.random.key(0)
        for i in range(max_new_tokens):
            out.append(tok)
            done = done | (tok == self.eos_id)
            pos = jnp.int32(T + i)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         pos, frontend)
            if greedy:
                tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            else:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, logits[:, -1]).astype(jnp.int32)
            if bool(done.all()):
                break
        return jnp.stack(out, axis=1)
