"""Continuous-batching serving engine: jitted prefill + chunked decode.

Architecture (DESIGN.md §Serving):

* **Slot table** — batch row == slot. The host-side `SlotScheduler`
  (serve/scheduler.py) admits queued requests into free slots and retires
  finished ones between jitted decode chunks, so the batch never blocks on
  its slowest member (the old engine's static batch did).
* **Per-slot positions** — the decode step takes a (B,) position vector;
  each KV cache row keys/masks on its own per-slot positions
  (models/layers.py), so sequences at different depths coexist in one
  decode GEMM batch. M = batch rows per GEMM is exactly the small-M
  latency regime the SA skewed pipeline targets.
* **Batched host syncs** — decode runs `sync_every` steps device-side in a
  single `lax.scan` before the one tokens fetch + scheduler tick per
  chunk; no per-token `bool(done.all())` blocking the dispatch queue.
* **Single-slot prefill** — an admission prefills (1, T_prompt) and the
  resulting cache fragment is dynamic-update-sliced into batch row `slot`
  of every cache leaf (they all carry batch at axis 1 — see
  model.init_cache). Prefill retraces per distinct prompt length; drivers
  should quantize prompt lengths to a small set. Right-padding prompts
  instead would corrupt SSM/hybrid states (padded tokens update the
  recurrence), so exact-length prefill is the correctness-first default.
* **Paged KV (default)** — under ``REPRO_KV=paged`` (the default; ``ring``
  is the A/B fallback) `serve()` replaces the per-slot fixed rings with a
  global page pool + per-slot block tables (DESIGN.md §5): the scheduler's
  `PageAllocator` hands pages out at admission and takes them back at
  retirement, so a long prompt can map many pages while short neighbours
  map few, and admission is gated on free *pages*, not free slots. The
  prefill fragment stays dense; `_insert` page-scatters it into the pool.
  `generate()` (static batches, frontend archs) always uses dense rings.
* **Disaggregated two-pool mode** (``REPRO_DISAGG=1`` / ``disagg=True``,
  DESIGN.md §10) — prefill and decode become separately-scheduled pools:
  prefill workers run dense batch-1 prefill into a staging fragment, the
  handoff scatters the finished pages whole into the shared pool
  (`_scatter` — ownership moves, not per-token copies), and the prefilled
  request waits on the scheduler's READY queue until a decode slot frees;
  binding then costs only the block-table splice (`_bind`). Decode chunks
  never wait on prefill compute — only on the handoff splice. The unified
  path's `_insert` is exactly `_scatter` + `_bind` composed in one jitted
  program, so the split cannot change tokens: ``REPRO_DISAGG=1|0`` is
  pinned token-identical on the stream digest (CI serve-smoke).
* **Prompt-length bucketing** (``REPRO_PREFILL_BUCKET=1`` /
  ``bucket_prompts=True``) — attention-only engines pad each prefill
  suffix up to a powers-of-two-ish bucket so mixed prompt-length streams
  share O(log) jit traces instead of one per distinct length; padded rows
  get positions -1 (invisible to the attention mask, like empty ring
  entries) and the first token reads the real last row via `last_index`.
  The summary's `prefill_compiles` counts distinct prefill traces either
  way.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import optflags
from repro.core.precision import current_policy, use_policy
from repro.kernels.ops import fused_decode_supported
from repro.models.config import ArchConfig
from repro.models import model as M
from repro.models.layers import KVCache, PagedKVCache
from repro.parallel import sharding as shardlib
from repro.train.step import (make_bucketed_prefill_step, make_draft_step,
                              make_prefill_step, make_serve_step,
                              make_verify_step)
from .scheduler import PageAllocator, SlotScheduler


def _round_up(x: int, m: int) -> int:
    return -(-int(x) // m) * m


def _bucket_len(n: int) -> int:
    """Smallest powers-of-two-ish length (8, 12, 16, 24, 32, 48, 64, …)
    ≥ n: neighbours are ≤ 1.5× apart, so bucketed prefill pads ≤ 50 % in
    the worst case while a mixed-length stream shares O(log) jit traces."""
    b = 8
    while b < n:
        b = b * 3 // 2 if (b & (b - 1)) == 0 else b * 4 // 3
    return b


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params: Any, batch: int,
                 cache_len: int, eos_id: int = 2, cache_dtype=jnp.float32,
                 sync_every: int = 8, kv_layout: str | None = None,
                 page_size: int = 16, pool_pages: int | None = None,
                 max_seq_len: int | None = None, spec_k: int | None = None,
                 spec_draft_layers: int | None = None,
                 disagg: bool | None = None, prefill_workers: int = 1,
                 bucket_prompts: bool | None = None):
        """`cache_len` is the per-request capacity of the ring layout and
        the pool-sizing reference of the paged one: by default the pool
        holds the same `batch · cache_len` tokens (plus the trash page) a
        dense ring allocation would, while `max_seq_len` (default
        `cache_len`, rounded up to a page) caps a single request and
        `pool_pages` overrides total pool size — so a paged engine can
        admit one long request beyond `cache_len` without paying dense
        rings of that length in every slot.

        `spec_k` (default: REPRO_SPEC_K, 0 = off) is the self-speculative
        draft length (DESIGN.md §9): each serve iteration drafts spec_k
        tokens with an early-exit forward over the first
        `spec_draft_layers` superblocks (default: half the stack) and
        verifies them in one batched M = spec_k+1 forward.

        `disagg` (default: REPRO_DISAGG) selects the two-pool serve loop
        (DESIGN.md §10); `prefill_workers` is how many prefills the
        prefill pool runs per decode chunk. `bucket_prompts` (default:
        REPRO_PREFILL_BUCKET) pads prefill suffixes to bucket lengths —
        see `bucketing_on` for the soundness gate."""
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.cache_dtype = cache_dtype
        self.sync_every = max(1, int(sync_every))
        kv_layout = kv_layout or os.environ.get("REPRO_KV", "paged")
        if kv_layout not in ("ring", "paged"):
            raise ValueError(
                f"REPRO_KV/kv_layout={kv_layout!r}; want 'ring' or 'paged'")
        if cfg.family == "ssm":
            kv_layout = "ring"   # no KV to page; SSM state is O(1) per slot
        self.kv_layout = kv_layout
        self.page_size = int(page_size)
        self.max_seq_len = _round_up(max_seq_len or cache_len, self.page_size)
        self.max_pages = self.max_seq_len // self.page_size
        self.pool_pages = int(
            pool_pages
            or _round_up(batch * cache_len, self.page_size) // self.page_size
            + 1)                 # +1: the reserved trash page
        # local-window rings survive in the paged layout (bounded by
        # `window`, they never strand capacity); the dense prefill fragment
        # must carry rings of the same length, so fragments are floored at
        # `window` tokens (and page allocations cover that floor)
        has_local = cfg.family != "ssm" and any(
            cfg.layer_kind(j).get("attn") == "local"
            for j in range(cfg.stack_period))
        self._frag_floor = (cfg.window if has_local and cfg.window
                            and cfg.window < self.max_seq_len else 1)
        self._prefill = jax.jit(make_prefill_step(cfg))
        # continued-prefill variants, one jitted closure per shared-prefix
        # length (prefix_len is trace-time state like the arithmetic mode);
        # drivers already quantize prompt lengths, and shared spans are
        # page-quantized, so the population stays small
        self._prefills: dict[int, Any] = {0: self._prefill}
        self._bucketed_prefills: dict[int, Any] = {}
        # distinct prefill trace shapes seen: (prefix_len, T, bucketed) —
        # the summary's `prefill_compiles`, the quantity bucketing exists
        # to shrink
        self._prefill_shapes: set[tuple[int, int, bool]] = set()
        self._disagg_arg = disagg
        self.prefill_workers = max(1, int(prefill_workers))
        self._bucket_arg = bucket_prompts
        self._serve_step = make_serve_step(cfg)
        self.spec_k = (optflags.spec_k() if spec_k is None
                       else max(0, int(spec_k)))
        n_super = cfg.num_layers // cfg.stack_period
        self.spec_draft_layers = (
            min(max(1, int(spec_draft_layers)), n_super)
            if spec_draft_layers else max(1, n_super // 2))
        # jit-key closure cache for decode chunks. spec_k is part of the
        # key (0 = the plain chunk): the spec chunk is a different traced
        # program over the same (steps, greedy, mode) tuple, and a shared
        # entry would silently serve whichever variant traced first — the
        # same aliasing the divergence probe hit with shared mode traces.
        self._chunks: dict[tuple[int, bool, str, int], Any] = {}
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._scatter = jax.jit(self._scatter_impl, donate_argnums=(0,))
        self._bind = jax.jit(self._bind_impl, donate_argnums=(0,))
        self._clear_slot = jax.jit(self._clear_slot_impl, donate_argnums=(0,))
        self._load_prefix = jax.jit(self._load_prefix_impl,
                                    static_argnums=(3,), donate_argnums=(0,))
        self.last_stats: dict[str, float] = {}

    def new_cache(self, batch: int | None = None):
        return M.init_cache(self.cfg, batch or self.batch, self.cache_len,
                            dtype=self.cache_dtype)

    def new_pool(self):
        """Paged serve cache: global page pools + per-slot block tables."""
        return M.init_cache(self.cfg, self.batch, self.max_seq_len,
                            dtype=self.cache_dtype,
                            paged=(self.pool_pages, self.page_size))

    def new_frag(self, prompt_len: int):
        """Dense batch-1 prefill fragment sized for one paged admission:
        the prompt rounded up to whole pages (and floored at `window` so
        local-ring leaves match the pool's)."""
        cap = _round_up(max(prompt_len, self._frag_floor), self.page_size)
        return M.init_cache(self.cfg, 1, cap, dtype=self.cache_dtype)

    def prefix_caching_on(self) -> bool:
        """Prefix sharing is sound only when every prompt page is a pure
        function of the prompt tokens (+ engine config): paged layout, no
        local-window dense rings (their fragment floor couples neighbours),
        no per-slot recurrent state (ssm/hybrid). REPRO_PREFIX_CACHE=0
        forces the allocate-and-prefill-everything fallback."""
        return (optflags.prefix_cache_enabled()
                and self.kv_layout == "paged"
                and self._frag_floor == 1
                and self.cfg.family != "ssm" and not self.cfg.hybrid)

    def disagg_on(self) -> bool:
        """The two-pool split is sound exactly where prefix sharing is:
        a handed-off page run must mean the same thing to whichever decode
        slot eventually binds it, i.e. pages must be a pure function of
        the prompt — paged layout, no local-window dense rings, no
        per-slot recurrent state. Opt-in (REPRO_DISAGG / constructor
        `disagg`); ineligible engines silently serve unified, same
        convention as `spec_decoding_on`."""
        on = (self._disagg_arg if self._disagg_arg is not None
              else optflags.disagg_enabled())
        return (on and self.kv_layout == "paged"
                and self._frag_floor == 1
                and self.cfg.family != "ssm" and not self.cfg.hybrid)

    def bucketing_on(self) -> bool:
        """Prompt-length bucketing is sound only for pure-attention
        stacks: right-padding advances ssm/hybrid recurrent state through
        garbage tokens, and local-window ring writes past the real length
        could wrap onto live rows. Opt-in (REPRO_PREFILL_BUCKET /
        constructor `bucket_prompts`)."""
        on = (self._bucket_arg if self._bucket_arg is not None
              else optflags.prefill_bucket_enabled())
        return (on and self._frag_floor == 1
                and self.cfg.family != "ssm" and not self.cfg.hybrid)

    def spec_decoding_on(self) -> bool:
        """Self-speculative decoding is armed (spec_k >= 1 and the
        REPRO_SPEC_DECODE kill-switch is up) *and* sound for this engine:
        rollback is a per-slot position non-advance, which only attention
        caches support — ssm/hybrid recurrent state advances on every
        forward and cannot un-see a rejected draft. A single-superblock
        stack has no depth to early-exit from (the draft would BE the
        model), and dense ring leaves (local windows; the ring layout)
        need spec_k+1 distinct ring slots or the verify block's writes
        would collide."""
        n_super = self.cfg.num_layers // self.cfg.stack_period
        min_ring = (self._frag_floor if self._frag_floor > 1
                    else (self.cache_len if self.kv_layout == "ring"
                          else None))
        return (optflags.spec_decode_enabled() and self.spec_k >= 1
                and n_super >= 2
                and self.cfg.family != "ssm" and not self.cfg.hybrid
                and (min_ring is None or self.spec_k + 1 <= min_ring))

    def _fingerprint(self) -> str:
        """Cache-key component isolating engines whose pages would not be
        interchangeable: arch/config, cache dtype, GEMM backend. The
        arithmetic *mode* (premium-exact vs bulk-approx) is keyed per
        request tier by the allocator, not here."""
        import hashlib
        raw = f"{self.cfg!r}|{jnp.dtype(self.cache_dtype).name}|" \
              f"{optflags.gemm_backend()}"
        return hashlib.sha1(raw.encode()).hexdigest()[:16]

    def new_allocator(self) -> PageAllocator:
        return PageAllocator(
            self.pool_pages, self.page_size,
            max_request_pages=self.max_pages,
            min_request_tokens=self._frag_floor,
            prefix_caching=self.prefix_caching_on(),
            fingerprint=self._fingerprint())

    # ------------------------------------------------------------------
    # jitted building blocks
    # ------------------------------------------------------------------

    @staticmethod
    def _scatter_impl(cache, frag, block_row, keep=0):
        """Pool half of the fragment splice: write a batch-1 fragment's
        rows into the global page pool WITHOUT touching any slot's block
        table. This is the disaggregated handoff (DESIGN.md §10) — the
        request may sit on the ready queue for many chunks before
        `_bind_impl` maps its pages into a decode slot, and until then no
        block table references them, so the writes race with nothing.

        The fragment's rows land at flat offsets
        `block_row[t // psz] · psz + t % psz`, after wiping the positions
        of *every* page in `block_row` to -1 — recycled pages still hold
        the previous owner's positions, which would otherwise be visible
        to the attention mask. `block_row` is the request's (max_pages,)
        page run, -1-padded.

        `keep` (prefix sharing) is the count of leading block-row pages
        that are cache-hit SHARED pages: they already hold the right KV,
        other readers may be attending to them concurrently, and this
        request must never write them — both the wipe and the scatter
        redirect those pages to the reserved trash page 0 (writes there
        are harmless by the same convention unmapped decode writes rely
        on). A COW'd tail page is NOT kept: its rows ride in the fragment
        (pre-loaded from the donor) and the scatter into the request's own
        page IS the copy-on-write. Dense leaves (local rings, ssm/conv
        state) pass through — they have no pool; `_insert_impl` row-splices
        them."""
        def splice(full, one):
            if not isinstance(full, PagedKVCache):
                return full
            n_super, n_pages, psz = full.k.shape[:3]
            s_frag = one.k.shape[2]
            npp = s_frag // psz
            lane = jnp.arange(psz, dtype=jnp.int32)
            dest_row = jnp.where(jnp.arange(npp) < keep, 0,
                                 block_row[:npp])
            # bucketed prefill fragments can round up past the allocated
            # run (-1 tail in block_row): those pages hold pure padding
            # (positions already -1), redirect them to the trash page
            dest_row = jnp.where(dest_row >= 0, dest_row, 0)
            dest = (dest_row[:, None] * psz + lane).reshape(-1)
            wipe_row = jnp.where(block_row >= 0, block_row, 0)
            wipe_row = jnp.where(
                jnp.arange(block_row.shape[0]) < keep, 0, wipe_row)
            wipe = (wipe_row[:, None]
                    * psz + lane).reshape(-1)   # page 0 wipe: harmless
            kf = full.k.reshape(n_super, n_pages * psz, *full.k.shape[3:])
            vf = full.v.reshape(n_super, n_pages * psz, *full.v.shape[3:])
            pf = full.positions.reshape(n_super, n_pages * psz)
            kf = kf.at[:, dest].set(one.k[:, 0].astype(kf.dtype))
            vf = vf.at[:, dest].set(one.v[:, 0].astype(vf.dtype))
            pf = pf.at[:, wipe].set(-1)
            pf = pf.at[:, dest].set(one.positions[:, 0])
            return PagedKVCache(kf.reshape(full.k.shape),
                                vf.reshape(full.v.shape),
                                pf.reshape(full.positions.shape),
                                full.block_table)

        return jax.tree.map(
            splice, cache, frag,
            is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))

    @staticmethod
    def _bind_impl(cache, block_row, slot):
        """Block-table half of the fragment splice: map an
        already-scattered page run into batch row `slot`. This is the ONLY
        device work a two-pool decode admission pays (admit_ready) — the
        KV itself was handed off at prefill completion."""
        def bind(leaf):
            if not isinstance(leaf, PagedKVCache):
                return leaf
            n_super = leaf.block_table.shape[0]
            bt = lax.dynamic_update_slice_in_dim(
                leaf.block_table,
                jnp.broadcast_to(block_row,
                                 (n_super, 1, block_row.shape[0])),
                slot, axis=1)
            return leaf._replace(block_table=bt)

        return jax.tree.map(
            bind, cache,
            is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))

    @staticmethod
    def _insert_impl(cache, frag, slot, block_row=None, keep=0):
        """Splice a batch-1 cache fragment into batch row `slot`.

        Dense leaves (rings, SSM/conv state, per-slot positions) carry
        batch at axis 1 (model.init_cache) and take a dynamic-update-slice.
        Paged pool leaves take `_scatter_impl`'s page scatter plus
        `_bind_impl`'s block-table splice — the unified path runs both
        halves in this one jitted program, the two-pool path runs them
        separately (scatter at handoff, bind at decode admission); either
        way the lowered writes are identical, which is why REPRO_DISAGG
        can never change tokens."""
        if block_row is not None:
            cache = ServeEngine._scatter_impl(cache, frag, block_row, keep)
            cache = ServeEngine._bind_impl(cache, block_row, slot)

        def splice(full, one):
            if isinstance(full, PagedKVCache):
                return full          # handled above
            if isinstance(full, KVCache):
                return KVCache(*(lax.dynamic_update_slice_in_dim(
                    f, o.astype(f.dtype), slot, axis=1)
                    for f, o in zip(full, one)))
            return lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1)

        return jax.tree.map(
            splice, cache, frag,
            is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))

    @staticmethod
    def _load_prefix_impl(frag, cache, src_row, prefix_len: int):
        """Load a shared prompt prefix from pool pages into a dense
        prefill fragment's first `prefix_len` rows (the continued prefill
        attends over them; layers.attention_block).

        `src_row` holds the ceil(prefix_len / psz) source page ids in
        sequence order: the cache-hit whole pages, plus — on a tail hit —
        the DONOR's partial page as the last entry (its rows are gathered
        here and later scattered into the request's own page by `_insert`,
        which completes the copy-on-write without a separate device pass).
        Positions are rebuilt as arange(prefix_len): by construction row t
        of a registered prompt run holds position t, and the donor's rows
        past the shared span (its own decode tokens) are cropped by the
        `[:prefix_len]` slice."""
        def load(one, full):
            if not isinstance(full, PagedKVCache):
                return one
            n_super, _, psz = full.k.shape[:3]
            lane = jnp.arange(psz, dtype=jnp.int32)
            src = (src_row[:, None] * psz + lane).reshape(-1)[:prefix_len]
            kf = full.k.reshape(n_super, -1, *full.k.shape[3:])[:, src]
            vf = full.v.reshape(n_super, -1, *full.v.shape[3:])[:, src]
            return KVCache(
                one.k.at[:, 0, :prefix_len].set(kf.astype(one.k.dtype)),
                one.v.at[:, 0, :prefix_len].set(vf.astype(one.v.dtype)),
                one.positions.at[:, 0, :prefix_len].set(
                    jnp.arange(prefix_len, dtype=jnp.int32)))

        return jax.tree.map(
            load, frag, cache,
            is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))

    def _prefill_for(self, prefix_len: int):
        """Jitted prefill closure for one static shared-prefix length."""
        fn = self._prefills.get(prefix_len)
        if fn is None:
            fn = jax.jit(make_prefill_step(self.cfg, prefix_len))
            self._prefills[prefix_len] = fn
        return fn

    def _bucketed_prefill_for(self, prefix_len: int):
        """Jitted bucketed-prefill closure (train.step
        make_bucketed_prefill_step); the padded token length is part of
        jit's shape key, so one closure serves every bucket."""
        fn = self._bucketed_prefills.get(prefix_len)
        if fn is None:
            fn = jax.jit(make_bucketed_prefill_step(self.cfg, prefix_len))
            self._bucketed_prefills[prefix_len] = fn
        return fn

    def _prefill_request(self, scheduler, req, cache, greedy: bool, rng):
        """Shared prefill body for the unified and two-pool paths: build
        the dense fragment (prefix-cache load + COW fork included), run
        the suffix prefill — bucketed when `bucketing_on()` — and pick the
        first token. Returns (frag, first, row, keep, rng) where `row` is
        the -1-padded (max_pages,) page run and `keep` the shared leading
        page count (both None for ring engines)."""
        paged = self.kv_layout == "paged"
        shared = req.shared_tokens if paged else 0
        suffix = req.prompt_len - shared
        Tb = suffix
        if self.bucketing_on():
            cap = self.max_seq_len if paged else self.cache_len
            b = _bucket_len(suffix)
            if shared + b <= cap:
                Tb = b
        frag = (self.new_frag(shared + Tb) if paged
                else self.new_cache(batch=1))
        if shared:
            # prefix-cache hit: pre-load the shared span's KV from the
            # hit pages (plus the COW donor's partial tail) and prefill
            # only the uncached suffix — TTFT stays honest, it times the
            # load + suffix prefill actually paid
            src = list(req.pages[:shared // self.page_size])
            if req.cow_src is not None:
                src.append(req.cow_src)
            frag = self._load_prefix(
                frag, cache, jnp.asarray(src, jnp.int32), shared)
            if req.cow_src is not None:
                # the donor's rows are in the fragment now; the scatter
                # writes them into the request's own tail page (the
                # copy), so the donor's copy-window lease can drop
                scheduler.cow_done(req)
        tokens = np.asarray(req.prompt[shared:], np.int32)
        if Tb != suffix or self.bucketing_on():
            tokens = np.pad(tokens, (0, Tb - suffix))
            self._prefill_shapes.add((shared, Tb, True))
            logits, frag = self._bucketed_prefill_for(shared)(
                self.params, jnp.asarray(tokens)[None], frag,
                jnp.asarray(suffix - 1, jnp.int32),
                jnp.asarray(req.prompt_len, jnp.int32))
        else:
            self._prefill_shapes.add((shared, suffix, False))
            logits, frag = self._prefill_for(shared)(
                self.params, jnp.asarray(tokens)[None], frag, None)
        if greedy:
            first = int(np.asarray(jnp.argmax(logits[0, -1])))
        else:
            rng, k = jax.random.split(rng)
            first = int(np.asarray(
                jax.random.categorical(k, logits[0, -1])))
        row = keep = None
        if paged:
            r = np.full((self.max_pages,), -1, np.int32)
            r[:len(req.pages)] = req.pages
            row = jnp.asarray(r)
            keep = jnp.asarray(shared // self.page_size, jnp.int32)
        return frag, first, row, keep, rng

    @staticmethod
    def _clear_slot_impl(cache, slot):
        """Unmap a freed slot's block-table rows (set to -1) so its decode
        writes fall to the trash page before the pages are reallocated."""
        def clear(leaf):
            if not isinstance(leaf, PagedKVCache):
                return leaf
            bt = leaf.block_table
            row = jnp.full((bt.shape[0], 1, bt.shape[2]), -1, bt.dtype)
            return leaf._replace(block_table=lax.dynamic_update_slice_in_dim(
                bt, row, slot, axis=1))
        return jax.tree.map(
            clear, cache,
            is_leaf=lambda x: isinstance(x, (KVCache, PagedKVCache)))

    def _must_reject(self, req) -> bool:
        """A just-admitted request the engine cannot serve.

        Paged: the allocator marked it unallocatable (more pages than the
        pool or the per-request block table holds). Ring: prompt + budget
        would wrap a global-attention ring (local windows and SSM state
        are the only wrap-safe caches)."""
        if self.kv_layout == "paged":
            return req.pages is None
        return (self.cfg.family != "ssm"
                and req.prompt_len + req.max_new_tokens > self.cache_len)

    def _chunk_fn(self, steps: int, greedy: bool, mode: str = "exact"):
        """steps decode iterations in one device-side lax.scan.

        Returns (tok, cache, pos, rng, toks (steps, B)); the caller fetches
        `toks` once per chunk — the only host sync on the decode path.

        `mode` selects the SA datapath for the chunk ("exact" | "approx" —
        the bulk serving tier). The precision policy is consulted at TRACE
        time, so mode is part of the jit-cache key and each variant is
        traced under its own `use_policy` scope — a shared traced callable
        would silently keep the mode it first saw."""
        key = (steps, greedy, mode, 0)   # 0: the non-speculative chunk
        if key not in self._chunks:
            serve_step = self._serve_step

            def chunk(params, tok, cache, pos, frontend, rng):
                def body(carry, _):
                    tok, cache, pos, rng = carry
                    logits, cache = serve_step(params, tok[:, None], cache,
                                               pos, frontend)
                    if greedy:
                        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                    else:
                        rng, k = jax.random.split(rng)
                        nxt = jax.random.categorical(
                            k, logits[:, -1]).astype(jnp.int32)
                    return (nxt, cache, pos + 1, rng), nxt

                (tok, cache, pos, rng), toks = lax.scan(
                    body, (tok, cache, pos, rng), length=steps)
                return tok, cache, pos, rng, toks

            jitted = jax.jit(chunk, donate_argnums=(2,))

            def run(*args, _jitted=jitted, _mode=mode):
                pol = dataclasses.replace(current_policy(), mode=_mode)
                with use_policy(pol):
                    return _jitted(*args)

            self._chunks[key] = run
        return self._chunks[key]

    def _spec_chunk_fn(self, iters: int, greedy: bool, mode: str, k: int):
        """`iters` draft-then-verify iterations in one device-side scan
        (DESIGN.md §9). Each iteration drafts k tokens with the early-exit
        step, scores them with one batched M=k+1 verify forward, and
        advances every slot by its accepted-prefix length + 1:

        * verify column t's target (argmax, or the sampled token) is the
          token the plain decode path would emit at position pos+t, so the
          longest prefix where draft == target is exactly correct output;
        * column `acc` rides free — its context is fully verified even
          when the draft at that column missed — so a reject-all
          iteration still emits one normal token;
        * rollback is the position non-advance itself: stale verify
          writes past the new position stay masked (kv_positions <= pos)
          and are overwritten in place by the next iteration's writes
          (positions only re-cover ground, never skip it).

        Returns (tok, cache, pos, rng, toks (iters, B, k+1),
        accs (iters, B)); the scheduler's `observe_spec` keeps
        toks[i, b, :accs[i, b] + 1] per iteration.
        """
        key = (iters, greedy, mode, k)
        if key not in self._chunks:
            draft_step = make_draft_step(self.cfg, self.spec_draft_layers)
            verify_step = make_verify_step(self.cfg)

            def chunk(params, tok, cache, pos, frontend, rng):
                del frontend             # serve() is text-only

                def body(carry, _):
                    tok, cache, pos, rng = carry

                    def draft_body(c, _):
                        dtok, dcache, dpos = c
                        dlogits, dcache = draft_step(params, dtok[:, None],
                                                     dcache, dpos)
                        nxt = jnp.argmax(dlogits[:, -1],
                                         -1).astype(jnp.int32)
                        return (nxt, dcache, dpos + 1), nxt

                    # the draft threads the shared cache: step i attends
                    # over step i-1's early-layer keys; the verify below
                    # rewrites every row the draft wrote (all layers ⊇
                    # early layers, pos..pos+k ⊇ pos..pos+k-1), so
                    # rejected drafts leave no live state
                    (_, cache, _), drafts = lax.scan(
                        draft_body, (tok, cache, pos), length=k)
                    drafts = drafts.T                         # (B, k)
                    block = jnp.concatenate([tok[:, None], drafts], axis=1)
                    logits, cache = verify_step(params, block, cache, pos)
                    if greedy:
                        out = jnp.argmax(logits, -1).astype(jnp.int32)
                    else:
                        rng, s = jax.random.split(rng)
                        out = jax.random.categorical(
                            s, logits).astype(jnp.int32)      # (B, k+1)
                    match = (drafts == out[:, :-1]).astype(jnp.int32)
                    acc = jnp.cumprod(match, axis=1).sum(axis=1)   # (B,)
                    tok = jnp.take_along_axis(out, acc[:, None],
                                              axis=1)[:, 0]
                    return (tok, cache, pos + acc + 1, rng), (out, acc)

                (tok, cache, pos, rng), (toks, accs) = lax.scan(
                    body, (tok, cache, pos, rng), length=iters)
                return tok, cache, pos, rng, toks, accs

            jitted = jax.jit(chunk, donate_argnums=(2,))

            def run(*args, _jitted=jitted, _mode=mode):
                pol = dataclasses.replace(current_policy(), mode=_mode)
                with use_policy(pol):
                    return _jitted(*args)

            self._chunks[key] = run
        return self._chunks[key]

    def spec_timing_probe(self, reps: int = 3) -> dict:
        """Per-iteration draft/verify wall split at this engine's serving
        shapes. serve() cannot time the two phases individually — they
        live inside one jitted scan, and a host sync between them would
        serialize the dispatch queue — so the driver's honest accounting
        (launch/serve.py) runs the same two device programs standalone on
        a fresh cache (identical shapes and tracing; an empty pool only
        changes data, not the op graph) and scales the measured costs by
        the spec iteration count. Returns {"draft_s", "verify_s"} per
        iteration."""
        k = self.spec_k
        draft_step = make_draft_step(self.cfg, self.spec_draft_layers)
        verify_step = make_verify_step(self.cfg)

        def draft_scan(params, tok, cache, pos):
            def body(c, _):
                dtok, dcache, dpos = c
                dlogits, dcache = draft_step(params, dtok[:, None], dcache,
                                             dpos)
                nxt = jnp.argmax(dlogits[:, -1], -1).astype(jnp.int32)
                return (nxt, dcache, dpos + 1), ()

            (tok, cache, _), _ = lax.scan(body, (tok, cache, pos), length=k)
            return tok, cache

        cache = (self.new_pool() if self.kv_layout == "paged"
                 else self.new_cache())
        tok = jnp.zeros((self.batch,), jnp.int32)
        pos = jnp.zeros((self.batch,), jnp.int32)
        block = jnp.zeros((self.batch, k + 1), jnp.int32)
        out = {}
        for name, fn, args in (
                ("draft_s", jax.jit(draft_scan),
                 (self.params, tok, cache, pos)),
                ("verify_s", jax.jit(verify_step),
                 (self.params, block, cache, pos))):
            jax.block_until_ready(fn(*args))     # compile + warm
            t = time.monotonic()
            r = None
            for _ in range(reps):
                r = fn(*args)
            jax.block_until_ready(r)
            out[name] = (time.monotonic() - t) / reps
        return out

    # ------------------------------------------------------------------
    # quality-tier instrumentation
    # ------------------------------------------------------------------

    def macs_per_token(self) -> int:
        """Model MACs per generated token ≈ total parameter count (every
        dense weight element contributes one MAC per token at decode;
        attention-score MACs are a small correction at decode depths).
        Feeds the per-tier energy model (core/energy.py)."""
        return int(sum(int(np.prod(x.shape))
                       for x in jax.tree.leaves(self.params)))

    def divergence_probe(self, prompt, steps: int = 16) -> dict:
        """Measure the bulk tier's output divergence against the exact
        datapath on this engine's weights.

        Teacher-forced A/B: prefill once on the exact path (prefill is
        always exact in `serve()` too), then feed the exact path's greedy
        tokens to BOTH datapaths from the same cache state and compare the
        per-step next-token logits. Each mode jits a *fresh closure* over
        the step — the precision policy is trace-time state and jit's
        trace cache keys on the wrapped function object, so re-jitting
        `self._serve_step` itself would reuse the first mode's trace.

        Returns {"steps", "max_ulp", "kl_mean", "max_abs_diff"}: max-ulp is
        the largest per-logit distance in units-in-the-last-place (ordered
        int32 mapping), kl_mean the mean per-step KL(exact ‖ approx) of the
        next-token distributions.
        """
        prompt = list(map(int, prompt))
        T = len(prompt)
        if T + steps > self.cache_len:
            raise ValueError(f"probe needs {T + steps} cache slots; "
                             f"engine has {self.cache_len}")
        exact_pol = dataclasses.replace(current_policy(), mode="exact")
        cache0 = self.new_cache(batch=1)
        with use_policy(exact_pol):
            prefill = jax.jit(make_prefill_step(self.cfg))
            logits, cache0 = prefill(
                self.params, jnp.asarray(prompt, jnp.int32)[None], cache0,
                None)
        first = int(np.asarray(jnp.argmax(logits[0, -1])))

        def fresh_step():
            # a new function object per call: jit must not share traces
            # across modes (see docstring)
            def step(params, tok, cache, pos, frontend,
                     _inner=self._serve_step):
                return _inner(params, tok, cache, pos, frontend)
            return jax.jit(step)

        def run_mode(mode, tokens):
            """Decode `steps` tokens under `mode`. `tokens[s]` (if set)
            teacher-forces step s's input; else greedy from step s-1."""
            pol = dataclasses.replace(current_policy(), mode=mode)
            step = fresh_step()
            out = []
            cache, tok = cache0, first
            with use_policy(pol):
                for s in range(steps):
                    if tokens is not None:
                        tok = tokens[s]
                    logits, cache = step(
                        self.params, jnp.asarray([[tok]], jnp.int32), cache,
                        jnp.asarray([T + s], jnp.int32), None)
                    row = np.asarray(logits[0, -1], np.float32)
                    out.append(row)
                    tok = int(row.argmax())
            return np.stack(out)

        le = run_mode("exact", None)
        # teacher-forced approx pass: replay the exact tokens so both modes
        # see identical inputs at every step (divergence is per-step, not
        # compounded through token choices)
        teacher = [first] + [int(r.argmax()) for r in le[:-1]]
        la = run_mode("approx", teacher)

        def ordered(x):
            b = x.view(np.int32).astype(np.int64)
            return np.where(b < 0, -(b & 0x7FFFFFFF), b)

        max_ulp = int(np.max(np.abs(ordered(le) - ordered(la))))
        pe = jax.nn.log_softmax(jnp.asarray(le), axis=-1)
        pa = jax.nn.log_softmax(jnp.asarray(la), axis=-1)
        kl = jnp.sum(jnp.exp(pe) * (pe - pa), axis=-1)
        return {"steps": int(steps), "max_ulp": max_ulp,
                "kl_mean": float(jnp.mean(kl)),
                "max_abs_diff": float(np.max(np.abs(le - la)))}

    # ------------------------------------------------------------------
    # static-batch generation (convenience / frontend archs)
    # ------------------------------------------------------------------

    def generate(self, prompts: jax.Array, max_new_tokens: int,
                 frontend=None, greedy: bool = True, rng=None):
        """prompts: (B, T_prompt) int32 → (B, ≤max_new_tokens) int32.

        Static batch: all B sequences prefill together and decode in
        lock-step. Decode runs in device-side chunks of `sync_every` steps;
        EOS is checked once per chunk on the fetched token block (the old
        per-token `bool(done.all())` blocked the dispatch queue every
        step), so an early-finishing batch stops at chunk granularity.
        `last_stats` records the prefill/decode wall split."""
        B, T = prompts.shape
        assert B == self.batch
        rng = rng if rng is not None else jax.random.key(0)
        t0 = time.monotonic()
        cache = self.new_cache()
        logits, cache = self._prefill(self.params, prompts, cache, frontend)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        first = np.asarray(tok)              # sync: prefill boundary (TTFT)
        t_prefill = time.monotonic() - t0
        pos = jnp.full((B,), T, jnp.int32)
        cols = [first]
        done = first == self.eos_id
        while len(cols) < max_new_tokens and not done.all():
            steps = min(self.sync_every, max_new_tokens - len(cols))
            tok, cache, pos, rng, toks = self._chunk_fn(steps, greedy)(
                self.params, tok, cache, pos, frontend, rng)
            t_np = np.asarray(toks)          # one sync per chunk
            cols.extend(t_np)
            done |= (t_np == self.eos_id).any(axis=0)
        self.last_stats = {"prefill_s": t_prefill,
                           "decode_s": time.monotonic() - t0 - t_prefill,
                           "decode_tokens": (len(cols) - 1) * B}
        return jnp.asarray(np.stack(cols, axis=1))

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------

    def serve(self, scheduler: SlotScheduler, greedy: bool = True, rng=None,
              clock=time.monotonic) -> dict:
        """Run the continuous-batching loop until the scheduler drains.

        Per-request results/metrics live on the `Request` objects
        (`scheduler.finished`); returns `scheduler.summary()` merged with
        the engine's prefill/decode wall-time split. Text-only for now:
        per-slot frontends would need fragment caches of their own.
        """
        assert scheduler.n_slots == self.batch, (
            scheduler.n_slots, self.batch)
        if self.cfg.family == "vlm" or self.cfg.is_encdec:
            # prefill/decode below run frontend=None: a vlm/enc-dec arch
            # would silently skip its encoder and generate garbage
            raise ValueError(
                "continuous serving is text-only (per-slot frontends are a "
                "ROADMAP item); use ServeEngine.generate for frontend archs")
        B = self.batch
        paged = self.kv_layout == "paged"
        if paged and scheduler.pages is None:
            scheduler.pages = self.new_allocator()
        rng = rng if rng is not None else jax.random.key(0)
        t0 = clock()
        skew = 0.0          # engine-time fast-forward for frozen clocks

        def now():
            return clock() - t0 + skew
        cache = self.new_pool() if paged else self.new_cache()
        tok = jnp.zeros((B,), jnp.int32)
        pos = jnp.zeros((B,), jnp.int32)
        prefill_s = decode_s = 0.0
        # per-phase wall split (honest accounting, DESIGN.md §10): handoff
        # = the page scatter / block-table splice walls; decode_stall = the
        # admission wall spent while ≥1 OTHER slot sat idle waiting — the
        # decode-blocking component. Unified mode charges the whole
        # prefill+insert to the stall (the slots genuinely wait on it);
        # two-pool mode charges only the handoff sync, the part a real
        # two-pool deployment (prefill on its own devices) would retain.
        # Single-host caveat: both pools share this process's device, so
        # the stall split is the modeled decode-blocking time, while
        # wall_s/ITL remain real measurements.
        handoff_s = decode_stall_s = 0.0
        disagg = self.disagg_on()
        mesh = shardlib.active_mesh()
        chunk_modes = {"exact": 0, "approx": 0}
        spec = self.spec_decoding_on()
        # a spec iteration emits 1..spec_k+1 tokens; size the chunk so its
        # *emission capacity* matches the plain chunk's sync_every tokens,
        # keeping admission latency (scheduler ticks happen at chunk
        # boundaries) comparable between the two paths
        spec_iters = (max(1, -(-self.sync_every // (self.spec_k + 1)))
                      if spec else 0)
        spec_chunks = 0

        # pre-compile the decode chunk before the timed loop: the first
        # call otherwise charges multi-second XLA compilation to decode_s
        # and drowns the steady-state rate the summary reports (the spec
        # chunk's draft-scan + verify graph compiles several times longer
        # than the plain chunk — exactly the A/B the accounting must not
        # skew). Safe on the fresh cache: block tables are unmapped (paged
        # writes fall to the trash page) and admission overwrites a ring/
        # ssm slot row wholesale; tok/pos/rng results are discarded, so
        # the token stream is byte-identical with or without the warmup.
        t_c = clock()
        warm = (self._spec_chunk_fn(spec_iters, greedy, "exact", self.spec_k)
                if spec else self._chunk_fn(self.sync_every, greedy))
        cache = warm(self.params, tok, cache, pos, None, rng)[1]
        jax.block_until_ready(cache)
        compile_s = clock() - t_c

        def clear_freed():
            # retirement freed the slot's pages; unmap its block-table rows
            # *before* the pages can be handed to a new admission, or the
            # stale slot's decode writes would corrupt the new owner (they
            # fall to the trash page once unmapped). Runs before admissions
            # (observe-retired slots) and again after them (a request whose
            # first token already finished it frees pages mid-admission;
            # its slot cannot be refilled within the same pass, so clearing
            # here never wipes a live row).
            nonlocal cache
            for freed in scheduler.drain_freed():
                cache = self._clear_slot(cache, freed)

        while not scheduler.drained():
            if paged:
                clear_freed()
            if disagg:
                # decode-pool admissions: bind already-prefilled requests
                # off the ready queue — a block-table splice, never
                # prefill compute — so free slots refill between chunks
                # at handoff cost only
                for slot in scheduler.free_slots():
                    req = scheduler.admit_ready(slot, now())
                    if req is None:
                        break
                    t_h = now()
                    r = np.full((self.max_pages,), -1, np.int32)
                    r[:len(req.pages)] = req.pages
                    cache = self._bind(cache, jnp.asarray(r), slot)
                    # first token came from finish_prefill; resume after it
                    tok = tok.at[slot].set(req.tokens[0])
                    pos = pos.at[slot].set(req.prompt_len)
                    handoff_s += now() - t_h
                # prefill pool: up to `prefill_workers` prefills per chunk
                # interval, stopping once the ready queue could refill
                # every slot (prefilling further ahead only pins pages
                # earlier for no latency win)
                n_pf = 0
                while (n_pf < self.prefill_workers
                       and scheduler.ready_depth() < B):
                    req = scheduler.begin_prefill(now())
                    if req is None:
                        break
                    if self._must_reject(req):
                        # the allocator found the request can never fit
                        # the pool / block table — retire it as rejected
                        scheduler.reject_prefill(req, now())
                        continue
                    t_p = now()
                    frag, first, row, keep, rng = self._prefill_request(
                        scheduler, req, cache, greedy, rng)
                    dt = now() - t_p
                    prefill_s += dt
                    # the handoff: reshard the staged fragment onto the
                    # pool's layout (page dim sharded over data axes —
                    # whole pages move, no per-token traffic), scatter it
                    # in, and sync — the one wall decode can block on
                    t_h = now()
                    if mesh is not None:
                        frag = shardlib.reshard_handoff(frag, mesh,
                                                        self.cfg)
                    cache = self._scatter(cache, frag, row, keep)
                    jax.block_until_ready(cache)
                    dt_h = now() - t_h
                    handoff_s += dt_h
                    if scheduler.num_active() > 0:
                        decode_stall_s += dt_h
                    # register BEFORE the scheduler sees the first token:
                    # a first-token EOS retires the request immediately,
                    # and the registered pages must park as cached, not
                    # return to the free list
                    scheduler.pages.prefix_register(req.prompt, req.pages,
                                                    req.tier)
                    scheduler.finish_prefill(req, first, now(),
                                             prefill_s=dt)
                    n_pf += 1
            else:
                for slot in scheduler.free_slots():
                    req = scheduler.admit(slot, now())
                    if req is None:
                        break
                    if self._must_reject(req):
                        # ring: a global-attention KV ring must never wrap
                        # (the write would overwrite live prompt keys and
                        # silently corrupt the request). Paged: the
                        # allocator found the request can never fit the
                        # pool / block table. Retire it as rejected —
                        # in-flight slots keep decoding.
                        scheduler.reject(slot, now())
                        continue
                    t_p = now()
                    frag, first, row, keep, rng = self._prefill_request(
                        scheduler, req, cache, greedy, rng)
                    t_h = now()
                    if paged:
                        cache = self._insert(cache, frag, slot, row, keep)
                        # register this prompt's pages for reuse BEFORE
                        # the scheduler sees the first token: a first-
                        # token EOS retires the request immediately, and
                        # the registered pages must park as cached, not
                        # return to the free list
                        scheduler.pages.prefix_register(req.prompt,
                                                        req.pages, req.tier)
                    else:
                        cache = self._insert(cache, frag, slot)
                    # dispatch-only wall: the unified splice overlaps the
                    # next admission, unlike the two-pool synced handoff
                    handoff_s += now() - t_h
                    tok = tok.at[slot].set(first)
                    pos = pos.at[slot].set(req.prompt_len)
                    dt = now() - t_p
                    prefill_s += dt
                    if scheduler.num_active() > 1:
                        # every other live slot sat idle through this
                        # admission's prefill — the stall disaggregation
                        # exists to remove
                        decode_stall_s += dt
                    scheduler.start(slot, first, now(), prefill_s=dt)
            if paged:
                clear_freed()

            if scheduler.num_active() == 0:
                if scheduler.ready_depth() > 0:
                    # staged-but-unbound work: the next pass binds it
                    continue
                # queue non-empty but nothing has arrived yet: wait for the
                # next arrival instead of spinning
                nxt = scheduler.next_arrival()
                if nxt is None:
                    break
                wait = nxt - now()
                if wait > 0:
                    before = clock()
                    time.sleep(min(wait, 0.05))
                    if clock() == before:
                        # injected/frozen clock: real sleeps cannot advance
                        # it — fast-forward engine time to the arrival
                        skew += wait
                continue

            # chunk datapath: approximate only when EVERY active slot is a
            # bulk request — premium never decodes on the approx path; bulk
            # slots sharing a chunk with premium get exact arithmetic (the
            # tier is a quality floor). Tier-affine admission (scheduler)
            # phase-separates mixed streams so all-bulk chunks do occur.
            active_tiers = {s.req.tier for s in scheduler.slots
                            if s.req is not None}
            mode = "approx" if active_tiers == {"bulk"} else "exact"
            chunk_modes[mode] += 1
            t_d = now()
            if spec:
                tok, cache, pos, rng, toks, accs = self._spec_chunk_fn(
                    spec_iters, greedy, mode, self.spec_k)(
                    self.params, tok, cache, pos, None, rng)
                toks_np = np.asarray(toks)   # the chunk's single host sync
                accs_np = np.asarray(accs)
                decode_s += now() - t_d
                spec_chunks += 1
                scheduler.observe_spec(toks_np, accs_np, now(), mode=mode)
            else:
                tok, cache, pos, rng, toks = self._chunk_fn(
                    self.sync_every, greedy, mode)(self.params, tok, cache,
                                                   pos, None, rng)
                toks_np = np.asarray(toks)   # the chunk's single host sync
                decode_s += now() - t_d
                scheduler.observe(toks_np, now(), mode=mode)

        summary = scheduler.summary()
        if chunk_modes["approx"]:
            summary |= {"chunks_exact": chunk_modes["exact"],
                        "chunks_approx": chunk_modes["approx"]}
        summary |= {"prefill_s": round(prefill_s, 4),
                    "decode_s": round(decode_s, 4),
                    "compile_s": round(compile_s, 4),
                    "wall_s": round(now(), 4),
                    # per-phase utilization split (see the accounting
                    # comment at the loop head): busy aliases keep the
                    # disagg A/B readable next to the stall/handoff walls
                    "prefill_busy_s": round(prefill_s, 4),
                    "decode_busy_s": round(decode_s, 4),
                    "handoff_s": round(handoff_s, 4),
                    "decode_stall_s": round(decode_stall_s, 4),
                    "prefill_compiles": len(self._prefill_shapes),
                    "disagg": disagg}
        if spec_chunks:
            summary |= {"spec_k": self.spec_k,
                        "spec_draft_layers": self.spec_draft_layers,
                        "spec_iters": spec_chunks * spec_iters}
        if paged:
            # which decode-attention path actually lowered into the chunk fn
            # (the knob is read at trace time; FP8 / non-fp32-out policies
            # fall back to gather regardless of the env setting)
            impl = optflags.decode_attn_impl()
            if impl == "fused" and not fused_decode_supported(current_policy()):
                impl = "gather"
            summary["decode_attn"] = impl
        served = summary["requests"] - summary["rejected"]
        if decode_s > 0 and served:
            # each *served* request's first token came from prefill, not
            # the decode chunks (rejected ones produced nothing at all)
            decode_tokens = summary["generated_tokens"] - served
            summary["decode_tok_s"] = round(decode_tokens / decode_s, 2)
        self.last_stats = summary
        return summary
