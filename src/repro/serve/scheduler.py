"""Continuous-batching slot scheduler (host-side control plane).

The engine owns the device state (params, KV/SSM cache, the per-slot token
and position vectors); the scheduler owns the *request* state: a FIFO
arrival queue, a slot table mapping batch rows to in-flight requests,
EOS / max-token completion, and per-request latency metrics. It never
touches jax — one scheduler tick per decode chunk is the only host work on
the decode path, so the dispatch queue stays full between syncs.

Semantics
---------
* A batch row of the decode step is a **slot**. A slot holds at most one
  request; finished slots are refilled from the queue between chunks
  instead of blocking the batch on its slowest member.
* Requests arrive at `arrival_time` (seconds on the engine's clock; 0 =
  already queued). Admission is FIFO among arrived requests.
* The engine decodes `sync_every` tokens device-side per chunk, then hands
  the whole (steps, B) token block to `observe()`. Tokens a slot produced
  *after* its EOS / token budget inside the chunk are discarded here and
  never counted — tok/s reports real generated tokens only.
* Completion timestamps are quantized to chunk boundaries (the host only
  observes tokens once per chunk); TTFT is exact (prefill is a sync point).
* With a `PageAllocator` attached (paged KV engines), admission is gated on
  **free pages, not free slots**: a request needing more pages than are
  currently free stays queued (head-of-line) until a retirement frees
  them, and one that can *never* fit (more pages than the pool or the
  per-request block table holds) is admitted with `pages=None` so the
  engine retires it as rejected. Pages free on retirement — EOS, budget,
  or rejection — so the pool can never leak across slot refills.
* Each request carries a **quality tier**: "premium" decodes on the exact
  round-once datapath, "bulk" may decode on the approximate-normalization
  datapath (core/chained_fma.approx_*). A decode chunk is shared by the
  whole batch, so the engine runs a chunk approximate only when *every*
  active slot is bulk — admission is therefore **tier-affine**: among
  arrived requests, one matching the active batch's (homogeneous) tier is
  preferred over the FIFO head, so tiers phase-separate and bulk chunks
  actually happen under mixed traffic. Premium requests never decode on
  the approximate path; bulk requests sharing a chunk with premium ones
  simply get exact arithmetic (quality floor, never a ceiling).
  `observe(..., mode=)` records which datapath produced each token, so
  the summary can report per-(tier, mode) token counts for the energy
  model (core/energy.py tier_energy_summary).
* **Two-pool mode** (disaggregated serving, DESIGN.md §10): the engine
  splits admission into a PREFILL pool (`begin_prefill` pulls arrived
  requests, takes their page leases, and `finish_prefill` stages the
  prefilled request + first token on a **ready queue**) and a DECODE pool
  (`admit_ready` binds staged requests to free slots between chunks — the
  only device work left is the block-table splice, so decode admissions
  never wait on prefill compute). Staging pages ARE pool pages: a request
  holds its leases from prefill admission through the ready queue to
  retirement, so the `pages_leaked == 0` invariant holds through the
  handoff. `ReplicaRouter` adds pick-least-loaded routing across N
  data-parallel engine replicas behind one arrival stream.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict, deque

import numpy as np

TIERS = ("premium", "bulk")


class PageAllocator:
    """Host-side refcounted free list over the global KV page pool, with an
    optional prefix cache.

    Page ids `[reserved, n_pages)` are allocatable; ids below `reserved`
    (default: page 0, the trash page decode writes of unmapped slots land
    in — see models/layers.py PagedKVCache) are never handed out.
    `max_request_pages` caps one request (the device block table's width).

    Every allocatable page carries a **refcount** — the number of live
    request leases mapping it. `alloc` hands out pages at refcount 1;
    `retain` bumps a shared page for an additional reader (prefix-cache
    hit); `free` releases one lease per page and only returns a page to the
    free list when its refcount reaches 0 *and* the prefix cache is not
    holding it. A page is therefore in exactly one of three states:

      free    — on the free deque (mirrored by `_free_set`, kept in
                lockstep so double-free detection is O(1), not a
                set-rebuild per retirement)
      leased  — refcount ≥ 1: mapped into at least one live block table
      cached  — refcount 0 but registered in the prefix index: its content
                (a prompt-prefix KV run) is retained for future admissions
                and reclaimed lazily under pool pressure (LRU run order)

    `pages_leaked` accounting is the remainder: in_use − leased − cached,
    which must stay 0 — cached-but-unleased prefix pages are *not* leaks.

    Prefix cache (`prefix_caching=True`): prompts are indexed at page
    granularity. Boundary key i maps `(fingerprint, tier, tokens[:i·psz])`
    to the page holding that whole page of prompt KV; an additional *tail*
    key maps the full prompt to its last partial page. Lookup walks the
    chain for the longest cached whole-page run (capped so at least one
    prompt token is always left to prefill — the admission needs last-token
    logits), then checks the tail key for an exact full-prompt match. The
    key carries the request **tier** because the approximate-normalization
    tiers (DESIGN.md §6) make the arithmetic mode part of a page's
    identity: a bulk stream must never be served a premium-exact prefix
    (or vice versa) or the divergence-probe premium-identity guarantee
    silently breaks. `fingerprint` isolates engines (params/config/dtype).

    Cached pages are strictly read-only: anyone who must write into a
    cached or multiply-leased page (the first divergent token of a fork)
    copies it first — copy-on-write, orchestrated by the engine via
    `cow_fork` accounting here.
    """

    def __init__(self, n_pages: int, page_size: int,
                 max_request_pages: int | None = None, reserved: int = 1,
                 min_request_tokens: int = 1, prefix_caching: bool = False,
                 fingerprint: str = ""):
        assert n_pages > reserved, (n_pages, reserved)
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.reserved = int(reserved)
        self.max_request_pages = (self.capacity if max_request_pages is None
                                  else int(max_request_pages))
        # floor on a request's token allocation: engines with local-window
        # rings prefill fragments of at least `window` tokens, so the pages
        # must cover that floor too (see engine.new_frag)
        self.min_request_tokens = int(min_request_tokens)
        self._free = deque(range(reserved, n_pages))
        self._free_set = set(self._free)      # lockstep mirror of _free
        self._refcount = [0] * n_pages
        self.leased = 0                       # pages with refcount >= 1
        self.peak_in_use = 0                  # high-water mark of `leased`
        # prefix cache state
        self.prefix_caching = bool(prefix_caching)
        self.fingerprint = str(fingerprint)
        self._index: dict[tuple, int] = {}    # boundary/tail key -> page id
        self._page_key: dict[int, tuple] = {}  # inverse (1:1 — a page is
        #                                        registered under one key)
        # run = the set of keys ONE registration added, LRU-ordered; the
        # eviction unit (evicting a chain's middle entry would orphan the
        # deeper pages, so whole runs go at once)
        self._runs: OrderedDict[tuple, list[tuple]] = OrderedDict()
        self._run_of_key: dict[tuple, tuple] = {}
        self.prefix_evictions = 0             # runs reclaimed under pressure
        self.cow_forks = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the reserved trash page)."""
        return self.n_pages - self.reserved

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.capacity - self.free_pages

    @property
    def cached(self) -> int:
        """Pages retained only by the prefix cache (refcount 0)."""
        return sum(1 for p in self._page_key if self._refcount[p] == 0)

    @property
    def leaked(self) -> int:
        """Pages neither free, leased, nor cached — must always be 0."""
        return self.in_use - self.leased - self.cached

    def _note_peak(self):
        # called wherever lease counts change — alloc, retain, free — so
        # refcount-bump admissions (cache hits that allocate nothing)
        # register peaks too, not just fresh allocations
        self.peak_in_use = max(self.peak_in_use, self.leased)

    def pages_needed(self, tokens: int) -> int:
        tokens = max(int(tokens), self.min_request_tokens, 1)
        return -(-tokens // self.page_size)

    def fits_ever(self, tokens: int) -> bool:
        """Could this request ever be admitted (given an empty pool)?"""
        n = self.pages_needed(tokens)
        return n <= min(self.capacity, self.max_request_pages)

    # ------------------------------------------------------------------
    # lease lifecycle
    # ------------------------------------------------------------------

    def _push_free(self, p: int):
        assert p not in self._free_set, ("double free", p)
        self._free.append(p)
        self._free_set.add(p)

    def _pop_free(self) -> int:
        p = self._free.popleft()
        self._free_set.remove(p)
        return p

    def allocatable(self, exclude: set[int] | None = None) -> int:
        """Pages an alloc could obtain right now: free plus cached pages in
        fully-idle runs (reclaimable via eviction). Runs containing any page
        in `exclude` are not counted — admission passes the pages it is
        about to retain, which pin their runs against eviction."""
        exclude = exclude or set()
        n = len(self._free)
        for keys in self._runs.values():
            pages = [self._index[k] for k in keys]
            if any(self._refcount[p] > 0 for p in pages):
                continue
            if exclude and not exclude.isdisjoint(pages):
                continue
            n += len(pages)
        return n

    def _evict_for(self, n: int):
        """Reclaim LRU fully-idle cached runs until `n` pages are free."""
        for run_id in list(self._runs):
            if len(self._free) >= n:
                break
            keys = self._runs[run_id]
            if any(self._refcount[self._index[k]] > 0 for k in keys):
                continue   # some page still leased: the run stays
            for k in keys:
                page = self._index.pop(k)
                del self._page_key[page]
                del self._run_of_key[k]
                self._push_free(page)
            del self._runs[run_id]
            self.prefix_evictions += 1

    def alloc(self, n: int) -> list[int] | None:
        """Lease `n` fresh pages (refcount 1 each), evicting idle cached
        prefix runs if the free list alone can't cover them; None if they
        aren't obtainable right now."""
        if n > self.max_request_pages:
            return None
        if n > len(self._free):
            self._evict_for(n)
        if n > len(self._free):
            return None
        pages = [self._pop_free() for _ in range(n)]
        for p in pages:
            assert self._refcount[p] == 0, p
            self._refcount[p] = 1
        self.leased += n
        self._note_peak()
        return pages

    def retain(self, pages: list[int]):
        """Add one lease per page (prefix-cache hit: a new block table maps
        already-resident pages; nothing is allocated)."""
        for p in pages:
            assert self.reserved <= p < self.n_pages, p
            assert p not in self._free_set, ("retain of a free page", p)
            if self._refcount[p] == 0:
                self.leased += 1
            self._refcount[p] += 1
        self._note_peak()

    def free(self, pages: list[int]):
        """Release one lease per page. A page whose last lease drops goes
        back to the free list unless the prefix cache retains it (then it
        parks as `cached` until evicted)."""
        for p in pages:
            assert self.reserved <= p < self.n_pages, p
            assert self._refcount[p] > 0, ("double free", p)
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self.leased -= 1
                if p not in self._page_key:
                    self._push_free(p)
        self._note_peak()

    def cow_fork(self, donor: int):
        """Account a copy-on-write fork: the caller copied `donor` into a
        freshly-`alloc`ed page device-side and remapped its block table;
        here the donor sheds that writer's lease (it stays cached/shared,
        read-only)."""
        self.cow_forks += 1
        self.free([donor])

    # ------------------------------------------------------------------
    # prefix cache
    # ------------------------------------------------------------------

    def _boundary_key(self, tier: str, prompt: list[int], i: int) -> tuple:
        return (self.fingerprint, tier, tuple(prompt[:i * self.page_size]))

    def _tail_key(self, tier: str, prompt: list[int]) -> tuple:
        return (self.fingerprint, tier, tuple(prompt), "tail")

    def prefix_lookup(self, prompt: list[int],
                      tier: str) -> tuple[list[int], int, int | None]:
        """Longest cached prefix of `prompt` under this tier's key space.

        Returns `(whole_pages, shared_tokens, tail_donor)`: the cached
        whole-page run (page ids in sequence order), the token count it
        covers, and — on an exact full-prompt match — the cached partial
        tail page to copy-on-write from (then `shared_tokens` is
        `len(prompt) - 1`: the last prompt token is always re-run so the
        admission has logits to sample the first generated token from).
        """
        if not self.prefix_caching:
            return [], 0, None
        plen = len(prompt)
        psz = self.page_size
        pages: list[int] = []
        touched: list[tuple] = []
        # cap the walk so >= 1 prompt token stays uncached (logits source)
        for i in range(1, (plen - 1) // psz + 1):
            key = self._boundary_key(tier, prompt, i)
            page = self._index.get(key)
            if page is None:
                break
            pages.append(page)
            touched.append(key)
        shared = len(pages) * psz
        tail_donor = None
        # a tail hit only pays when it extends sharing past the whole-page
        # run (plen-1 > W*psz, i.e. >= 2 prompt tokens on the tail page) —
        # otherwise the device copy buys nothing
        if len(pages) == plen // psz and plen % psz >= 2:
            key = self._tail_key(tier, prompt)
            tail_donor = self._index.get(key)
            if tail_donor is not None:
                shared = plen - 1
                touched.append(key)
        for key in touched:                    # LRU touch per involved run
            run = self._run_of_key.get(key)
            if run is not None and run in self._runs:
                self._runs.move_to_end(run)
        return pages, shared, tail_donor

    def prefix_register(self, prompt: list[int], pages: list[int],
                        tier: str) -> int:
        """Register a freshly-prefilled prompt's pages for reuse: one entry
        per whole prompt page plus a tail entry for the partial last page.
        Entries whose key already exists are skipped (first registrant
        wins; identical arithmetic makes the pages bit-identical anyway).

        The registrant keeps decoding into the tail page — that is safe:
        its decode writes land at rows >= plen % psz, past the cached
        prompt rows, and a future reader COW-copies the page then masks
        whatever stale rows it didn't overwrite by position (the same
        invariant normal paged decode relies on for recycled pages).
        Returns the number of pages newly registered."""
        if not self.prefix_caching:
            return 0
        plen = len(prompt)
        psz = self.page_size
        added: list[tuple] = []
        run_id = (self.fingerprint, tier, tuple(prompt))
        for i in range(1, plen // psz + 1):
            key = self._boundary_key(tier, prompt, i)
            if key in self._index:
                continue
            self._register_one(key, pages[i - 1], run_id, added)
        # tail entries with < 2 prompt rows never beat the whole-page run
        # (see prefix_lookup) — don't park a page in the cache for them
        if plen % psz >= 2:
            key = self._tail_key(tier, prompt)
            if key not in self._index:
                self._register_one(key, pages[plen // psz], run_id, added)
        if added:
            self._runs.setdefault(run_id, []).extend(added)
            self._runs.move_to_end(run_id)
        return len(added)

    def _register_one(self, key: tuple, page: int, run_id: tuple,
                      added: list[tuple]):
        assert page not in self._page_key, (page, "already registered")
        self._index[key] = page
        self._page_key[page] = key
        self._run_of_key[key] = run_id
        added.append(key)


@dataclasses.dataclass
class Request:
    """One generation request and (after serving) its result + metrics."""
    rid: int
    prompt: list[int]
    max_new_tokens: int
    arrival_time: float = 0.0
    tier: str = "premium"                # "premium" (exact) | "bulk" (approx)

    # filled in by the scheduler as the request is served
    tokens: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    # KV pages mapped at admission (paged engines; leases released on
    # retirement, the list is kept as a record). None after admission =
    # could never fit the pool / block table — the engine retires it as
    # rejected. With prefix caching the first `shared_tokens // page_size`
    # entries are cache-hit pages (retained, not allocated).
    pages: list[int] | None = None
    # prompt tokens whose KV is served from the prefix cache (prefill
    # resumes at this offset; 0 = full prefill)
    shared_tokens: int = 0
    # cached partial tail page to copy-on-write from before this request's
    # first write (engine copies device-side into pages[shared_tokens //
    # page_size] then reports the fork; cleared back to None once done)
    cow_src: int | None = None
    t_admitted: float | None = None
    t_first_token: float | None = None   # TTFT reference point
    t_done: float | None = None
    prefill_s: float = 0.0
    finish_reason: str = ""              # "eos" | "length"

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def n_generated(self) -> int:
        """Real generated tokens (post-EOS chunk padding never lands here)."""
        return len(self.tokens)

    @property
    def ttft(self) -> float | None:
        if self.t_first_token is None:
            return None
        return self.t_first_token - self.arrival_time

    @property
    def decode_tok_s(self) -> float | None:
        """Decode-only rate: tokens after the first / time after TTFT."""
        if self.t_done is None or self.t_first_token is None:
            return None
        dt = self.t_done - self.t_first_token
        return (self.n_generated - 1) / dt if dt > 0 else None


@dataclasses.dataclass
class _Slot:
    req: Request | None = None


class SlotScheduler:
    """Slot table + arrival queue + per-request accounting."""

    def __init__(self, n_slots: int, eos_id: int = 2,
                 pages: PageAllocator | None = None):
        self.n_slots = n_slots
        self.eos_id = eos_id
        self.pages = pages        # set by paged engines (serve() injects one)
        self.pending: deque[Request] = deque()
        # two-pool mode only (begin_prefill/finish_prefill/admit_ready):
        # prefilled requests staged for a decode slot, FIFO by prefill
        # completion; unified engines never touch it
        self.ready: deque[Request] = deque()
        self.slots = [_Slot() for _ in range(n_slots)]
        self.finished: list[Request] = []
        self.depth_samples: list[int] = []
        self.ready_depth_samples: list[int] = []
        self._two_pool = False    # flipped by begin_prefill; gates summary
        self.page_util_samples: list[float] = []
        self.page_blocks = 0      # requests that ever waited for free pages
        self._blocked_rids: set[int] = set()
        self.refills = 0          # admissions into a previously-used slot
        self._slot_used = [False] * n_slots
        self._freed_slots: list[int] = []
        self._next_rid = 0
        # real generated tokens by (tier, datapath mode) — the energy
        # model's input. Prefill/first tokens are always exact; bulk
        # tokens decoded in a mixed (exact) chunk are counted honestly
        # as ("bulk", "exact").
        self.tier_mode_tokens: dict[tuple[str, str], int] = {}
        self.tier_affine_picks = 0   # admissions that skipped the FIFO head
        self.prefix_hits = 0         # admissions that mapped cached pages
        self.prefix_tokens_saved = 0  # prompt tokens not re-prefilled
        # speculative-decode accounting (engine.observe_spec): drafted
        # counts every draft token a live slot's iteration proposed,
        # accepted the ones the verify forward agreed with — the ratio is
        # the acceptance rate the draft-cost tradeoff lives or dies on
        self.spec_drafted = 0
        self.spec_accepted = 0
        # histogram of per-iteration accepted-prefix lengths (0 = reject-
        # all, k = the whole draft); live slots only
        self.spec_accept_hist: dict[int, int] = {}

    # ------------------------------------------------------------------
    # submission / admission
    # ------------------------------------------------------------------

    def submit(self, prompt, max_new_tokens: int,
               arrival_time: float = 0.0, tier: str = "premium") -> Request:
        if tier not in TIERS:
            raise ValueError(f"unknown tier {tier!r}; have {TIERS}")
        req = Request(rid=self._next_rid, prompt=list(map(int, prompt)),
                      max_new_tokens=int(max_new_tokens),
                      arrival_time=float(arrival_time), tier=tier)
        self._next_rid += 1
        # keep the queue sorted by arrival (stable: ties stay in submit
        # order), so admission is FIFO among *arrived* requests — a late
        # submit with an early arrival_time must not be head-of-line
        # blocked behind a future arrival
        i = len(self.pending)
        while i > 0 and self.pending[i - 1].arrival_time > req.arrival_time:
            i -= 1
        self.pending.insert(i, req)
        return req

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s.req is None]

    def next_arrival(self) -> float | None:
        return self.pending[0].arrival_time if self.pending else None

    def _active_tier(self) -> str | None:
        """The batch's tier iff every active slot shares one, else None."""
        tiers = {s.req.tier for s in self.slots if s.req is not None}
        return tiers.pop() if len(tiers) == 1 else None

    def _select_pending(self, now: float) -> int | None:
        """Index of the pending request to admit next: the earliest-arrived
        one matching the active batch's homogeneous tier (tier-affine — so
        mixed streams phase-separate and all-bulk chunks can run the
        approximate datapath), else the FIFO head. Returns None when
        nothing has arrived by `now`."""
        if not self.pending or self.pending[0].arrival_time > now:
            return None
        tier = self._active_tier()
        if tier is not None and self.pending[0].tier != tier:
            for i, req in enumerate(self.pending):
                if req.arrival_time > now:
                    break
                if req.tier == tier:
                    return i
        return 0

    def _page_transaction(self, cand: Request) -> bool:
        """Page-gate one candidate and, on success, take its leases and
        fill `pages/shared_tokens/cow_src` in place. Returns False when
        the candidate must stay queued (could fit an empty pool but not
        the current one); a candidate that can NEVER fit passes with
        `pages=None` for the engine to reject. Shared by the unified path
        (`admit`) and the prefill pool (`begin_prefill`)."""
        tokens = cand.prompt_len + cand.max_new_tokens
        if not self.pages.fits_ever(tokens):
            cand.pages = None
            return True
        needed = self.pages.pages_needed(tokens)
        hit, shared, donor = self.pages.prefix_lookup(cand.prompt, cand.tier)
        fresh = needed - len(hit)
        pinned = set(hit) | ({donor} if donor is not None else set())
        if fresh > self.pages.allocatable(pinned):
            # count *requests* that waited, not poll attempts — the
            # loop re-asks every chunk tick while the head is blocked
            if cand.rid not in self._blocked_rids:
                self._blocked_rids.add(cand.rid)
                self.page_blocks += 1
            return False
        # transaction: pin the hit pages (+ COW donor) with leases FIRST
        # so the fresh alloc's eviction pass cannot reclaim them, then
        # allocate the remainder — the allocatable() gate above
        # guarantees this succeeds
        if pinned:
            self.pages.retain(hit + ([donor] if donor is not None else []))
        fresh_pages = self.pages.alloc(fresh)
        assert fresh_pages is not None, (fresh, "gate lied")
        cand.pages = hit + fresh_pages
        cand.shared_tokens = shared
        cand.cow_src = donor
        if shared:
            self.prefix_hits += 1
            self.prefix_tokens_saved += shared
        return True

    def admit(self, slot_idx: int, now: float) -> Request | None:
        """Admit the next pending request (see `_select_pending`) into
        `slot_idx` if one has arrived by `now`.

        With a page allocator attached, admission is additionally gated on
        free pages: a candidate that could fit an empty pool but not the
        current one stays queued (returns None — the slot idles until a
        retirement frees pages); one that could never fit is admitted with
        `pages=None` for the engine to reject."""
        i = self._select_pending(now)
        if i is None:
            return None
        cand = self.pending[i]
        if self.pages is not None and not self._page_transaction(cand):
            return None
        req = cand
        del self.pending[i]
        if i > 0:
            self.tier_affine_picks += 1
        req.slot = slot_idx
        req.t_admitted = now
        if self._slot_used[slot_idx]:
            self.refills += 1
        self._slot_used[slot_idx] = True
        self.slots[slot_idx].req = req
        return req

    def reject(self, slot_idx: int, now: float,
               reason: str = "rejected") -> Request:
        """Retire the just-admitted request without serving it (e.g. the
        engine found it cannot fit the cache); the batch keeps going."""
        slot = self.slots[slot_idx]
        req = slot.req
        assert req is not None
        self._finish(slot, req, reason, now)
        return req

    def start(self, slot_idx: int, first_token: int, now: float,
              prefill_s: float = 0.0):
        """Record the prefill's argmax token (the first generated token)."""
        slot = self.slots[slot_idx]
        req = slot.req
        assert req is not None
        req.t_first_token = now
        req.prefill_s = prefill_s
        self._accept(slot, req, int(first_token), now)

    # ------------------------------------------------------------------
    # two-pool admission (disaggregated serving, DESIGN.md §10)
    # ------------------------------------------------------------------

    def ready_depth(self) -> int:
        return len(self.ready)

    def begin_prefill(self, now: float) -> Request | None:
        """Pull the next arrived request into the PREFILL pool. Page-gated
        exactly like `admit` — staging pages ARE pool pages (the handoff
        moves ownership, not bytes between pools), so a request holds its
        leases from here through the ready queue to retirement and the
        `pages_leaked == 0` invariant holds at every point. Selection is
        plain FIFO among arrived requests: tier affinity is applied
        downstream at `admit_ready`, where the decode batch whose tier
        matters actually lives. Returns None when nothing has arrived by
        `now` or the head is blocked on pages. A returned request with
        `pages=None` can never fit — retire it via `reject_prefill`."""
        self._two_pool = True
        if not self.pending or self.pending[0].arrival_time > now:
            return None
        cand = self.pending[0]
        if self.pages is not None and not self._page_transaction(cand):
            return None
        self.pending.popleft()
        cand.t_admitted = now
        return cand

    def reject_prefill(self, req: Request, now: float,
                       reason: str = "rejected") -> Request:
        """Retire a prefill-pool request without serving it (it can never
        fit the pool / block table); it never held a decode slot."""
        self._finish(None, req, reason, now)
        return req

    def finish_prefill(self, req: Request, first_token: int, now: float,
                       prefill_s: float = 0.0) -> bool:
        """Prefill-pool completion: record TTFT and the first generated
        token, then stage the request on the ready queue for the decode
        pool. A first-token EOS (or a 1-token budget) finishes the request
        right here — its pages free (or park as prefix-cached) without
        ever touching a decode slot. Returns True iff staged."""
        req.t_first_token = now
        req.prefill_s = prefill_s
        self._accept(None, req, int(first_token), now)
        if req.t_done is None:
            self.ready.append(req)
            return True
        return False

    def admit_ready(self, slot_idx: int, now: float) -> Request | None:
        """Bind the next ready (already-prefilled) request to a free
        decode slot. Tier-affine like `_select_pending` — a staged request
        matching the active batch's homogeneous tier is preferred — so the
        two-pool engine phase-separates mixed streams exactly like the
        unified one. The only device work this admission needs is the
        block-table splice (engine._bind): the KV pages were handed off at
        prefill completion."""
        if not self.ready:
            return None
        i = 0
        tier = self._active_tier()
        if tier is not None and self.ready[0].tier != tier:
            for j, r in enumerate(self.ready):
                if r.tier == tier:
                    i = j
                    break
        req = self.ready[i]
        del self.ready[i]
        if i > 0:
            self.tier_affine_picks += 1
        req.slot = slot_idx
        if self._slot_used[slot_idx]:
            self.refills += 1
        self._slot_used[slot_idx] = True
        self.slots[slot_idx].req = req
        return req

    # ------------------------------------------------------------------
    # decode ticks
    # ------------------------------------------------------------------

    def positions(self) -> np.ndarray:
        """(B,) int32 next-decode positions, derived from request progress
        (free slots report 0). Introspection/tests only — the engine's
        device-side pos vector is the single authoritative copy."""
        return np.array(
            [0 if s.req is None
             else s.req.prompt_len + max(1, s.req.n_generated) - 1
             for s in self.slots], np.int32)

    def num_active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    def drained(self) -> bool:
        return (not self.pending and not self.ready
                and self.num_active() == 0)

    def observe(self, chunk_tokens: np.ndarray, now: float,
                mode: str = "exact"):
        """Consume one decode chunk: (steps, B) tokens fetched from device.

        Row s of the chunk is the token each slot emitted at step s. Tokens
        for free slots, and steps after a slot finished mid-chunk, are
        discarded (the device keeps decoding every row; the garbage never
        reaches a request).

        `mode` is the datapath the engine ran this chunk on ("exact" |
        "approx"); accepted tokens are credited to (tier, mode) for the
        energy accounting.
        """
        steps, B = chunk_tokens.shape
        assert B == self.n_slots, (B, self.n_slots)
        for s in range(steps):
            for i, slot in enumerate(self.slots):
                if slot.req is None:
                    continue
                self._accept(slot, slot.req, int(chunk_tokens[s, i]), now,
                             mode=mode)
        self.depth_samples.append(len(self.pending))
        self.ready_depth_samples.append(len(self.ready))
        if self.pages is not None and self.pages.capacity:
            self.page_util_samples.append(
                self.pages.in_use / self.pages.capacity)

    def observe_spec(self, chunk_tokens: np.ndarray, accepted: np.ndarray,
                     now: float, mode: str = "exact"):
        """Consume one speculative decode chunk (engine._spec_chunk_fn):
        `chunk_tokens` (iters, B, k+1) verify-target tokens, `accepted`
        (iters, B) accepted-prefix lengths. Iteration s of slot i emitted
        `chunk_tokens[s, i, :accepted[s, i] + 1]` — the accepted draft
        prefix plus the free verify token; the rejected tail is rolled
        back on device (position non-advance) and discarded here. EOS or
        budget exhaustion inside an iteration retires the request between
        tokens, so post-EOS emissions are dropped exactly like post-finish
        steps in plain `observe`.
        """
        iters, B, k1 = chunk_tokens.shape
        assert B == self.n_slots, (B, self.n_slots)
        for s in range(iters):
            for i, slot in enumerate(self.slots):
                if slot.req is None:
                    continue
                a = int(accepted[s, i])
                self.spec_drafted += k1 - 1
                self.spec_accepted += a
                self.spec_accept_hist[a] = (
                    self.spec_accept_hist.get(a, 0) + 1)
                for t in range(a + 1):
                    if slot.req is None:     # finished mid-iteration
                        break
                    self._accept(slot, slot.req, int(chunk_tokens[s, i, t]),
                                 now, mode=mode)
        self.depth_samples.append(len(self.pending))
        self.ready_depth_samples.append(len(self.ready))
        if self.pages is not None and self.pages.capacity:
            self.page_util_samples.append(
                self.pages.in_use / self.pages.capacity)

    def _accept(self, slot: _Slot | None, req: Request, token: int,
                now: float, mode: str = "exact"):
        # slot=None: prefill-pool request not yet bound to a decode slot
        # (two-pool mode's finish_prefill)
        req.tokens.append(token)
        key = (req.tier, mode)
        self.tier_mode_tokens[key] = self.tier_mode_tokens.get(key, 0) + 1
        if token == self.eos_id:
            self._finish(slot, req, "eos", now)
        elif req.n_generated >= req.max_new_tokens:
            self._finish(slot, req, "length", now)

    def cow_done(self, req: Request):
        """The engine finished copying `req.cow_src` into the request's own
        tail page: release the donor's copy-window lease (it stays cached
        for the next reader) and count the fork."""
        assert req.cow_src is not None
        self.pages.cow_fork(req.cow_src)
        req.cow_src = None

    def _finish(self, slot: _Slot | None, req: Request, reason: str,
                now: float):
        req.finish_reason = reason
        req.t_done = now
        self.finished.append(req)
        if slot is not None:
            slot.req = None
        if self.pages is not None and req.pages:
            # every retirement path — EOS, budget, rejection — returns the
            # request's pages; `req.pages` stays as the record of what ran
            self.pages.free(req.pages)
            if req.cow_src is not None:
                # retired before the engine ran the COW copy (e.g. engine
                # rejection): drop the donor's copy-window lease too
                self.pages.free([req.cow_src])
                req.cow_src = None
        if req.slot >= 0:
            self._freed_slots.append(req.slot)

    def drain_freed(self) -> list[int]:
        """Slots freed since the last call (any retirement reason). Paged
        engines use this to clear the freed rows' device block tables
        before the pages can be reallocated to another slot."""
        freed, self._freed_slots = self._freed_slots, []
        return freed

    # ------------------------------------------------------------------
    # metrics
    # ------------------------------------------------------------------

    def summary(self) -> dict:
        done = self.finished
        ttfts = [r.ttft for r in done if r.ttft is not None]
        gen = sum(r.n_generated for r in done)
        out = {
            "requests": len(done),
            "generated_tokens": gen,       # real tokens, no post-EOS padding
            "prompt_tokens": sum(r.prompt_len for r in done),
            "eos_finishes": sum(1 for r in done if r.finish_reason == "eos"),
            "rejected": sum(1 for r in done
                            if r.finish_reason == "rejected"),
            "slot_refills": self.refills,
            "mean_queue_depth": float(np.mean(self.depth_samples))
            if self.depth_samples else 0.0,
            "max_queue_depth": max(self.depth_samples, default=0),
        }
        if self._two_pool:
            # ready-queue depth percentiles (sampled per decode chunk,
            # like depth_samples): how far ahead the prefill pool runs
            rd = self.ready_depth_samples or [0]
            out["ready_depth_p50"] = float(np.percentile(rd, 50))
            out["ready_depth_p90"] = float(np.percentile(rd, 90))
            out["ready_depth_max"] = int(max(rd))
        if ttfts:
            out["ttft_mean_s"] = float(np.mean(ttfts))
            out["ttft_max_s"] = float(np.max(ttfts))
        rates = [r.decode_tok_s for r in done if r.decode_tok_s]
        if rates:
            out["decode_tok_s_mean_per_req"] = float(np.mean(rates))
        if any(r.tier != "premium" for r in done) or any(
                m != "exact" for _, m in self.tier_mode_tokens):
            # tier section only when the stream actually used the knob
            out["tier_requests"] = {
                t: sum(1 for r in done if r.tier == t) for t in TIERS
                if any(r.tier == t for r in done)}
            out["tier_mode_tokens"] = {
                f"{t}/{m}": n
                for (t, m), n in sorted(self.tier_mode_tokens.items())}
            out["tier_affine_picks"] = self.tier_affine_picks
        if self.spec_drafted:
            out |= {
                "spec_drafted": self.spec_drafted,
                "spec_accepted": self.spec_accepted,
                "spec_accept_rate": round(
                    self.spec_accepted / self.spec_drafted, 4),
            }
        if self.pages is not None:
            out |= {
                "page_size": self.pages.page_size,
                "pages_total": self.pages.capacity,
                "pages_peak_in_use": self.pages.peak_in_use,
                # three-way split: leased (live block tables), cached
                # (prefix index retains them, refcount 0 — NOT leaks),
                # leaked (unaccounted — must be 0, drained or not)
                "pages_leased": self.pages.leased,
                "pages_cached": self.pages.cached,
                "pages_leaked": self.pages.leaked,
                "page_blocks": self.page_blocks,
                "page_util_mean": round(float(
                    np.mean(self.page_util_samples)), 4)
                if self.page_util_samples else 0.0,
            }
            if self.pages.prefix_caching:
                out |= {
                    "prefix_hits": self.prefix_hits,
                    "prefix_tokens_saved": self.prefix_tokens_saved,
                    "cow_forks": self.pages.cow_forks,
                    "prefix_evictions": self.pages.prefix_evictions,
                }
        return out


class ReplicaRouter:
    """Pick-least-loaded routing across N data-parallel engine replicas
    behind one arrival stream (DESIGN.md §10). Load is the outstanding
    token estimate — prompt plus decode budget of everything routed to a
    replica and not yet reported complete — so a burst of long-prompt
    requests spreads instead of round-robining onto one replica. Ties
    break to the lowest index, which makes routing a pure function of the
    submitted stream: replica assignment never depends on wall clock, so
    the REPRO_DISAGG digest contract extends across replicas."""

    def __init__(self, n_replicas: int):
        if n_replicas < 1:
            raise ValueError(f"n_replicas={n_replicas}; want >= 1")
        self.n = int(n_replicas)
        self.outstanding = [0] * self.n   # token estimate in flight
        self.routed = [0] * self.n        # requests sent, lifetime

    def route(self, prompt_len: int, max_new_tokens: int) -> int:
        i = min(range(self.n), key=lambda j: (self.outstanding[j], j))
        self.outstanding[i] += int(prompt_len) + int(max_new_tokens)
        self.routed[i] += 1
        return i

    def complete(self, replica: int, prompt_len: int, max_new_tokens: int):
        """Report a routed request finished. Online servers call this per
        retirement; the offline driver routes the whole stream up-front
        against the submit-time estimates and never calls it."""
        self.outstanding[replica] -= int(prompt_len) + int(max_new_tokens)
        assert self.outstanding[replica] >= 0, (replica, "over-completed")
