"""Area / power / energy model for the two SA pipeline designs (paper §IV).

The paper's synthesis results (Catapult HLS → Oasys, 45 nm, 1 GHz, 128×128
PEs, Bfloat16 inputs / FP32 reduction, power via PowerPro):

  * skewed design area  = 1.09 × baseline  (extra pipeline registers for the
    intermediate ê / LZA forwards + the exponent-fix logic)
  * skewed design power = 1.07 × baseline  (average, across CNN layers)

Energy per layer is `power × latency`; the paper's headline result is that the
skew's latency savings amortize its power overhead: per-layer energy *rises*
for early CNN layers (M ≫ array fill time ⇒ tiny latency gain < 7 % power
cost) and *falls* sharply for late layers (small spatial M, many K/N tiles ⇒
the 2R→R fill saving dominates) — Figs. 7 & 8 — netting −8 % (MobileNet) /
−11 % (ResNet50) total energy.
"""
from __future__ import annotations

import dataclasses

from .systolic import BASELINE, SKEWED, SAConfig
from . import workloads as wl

# A third design point beyond the paper: the skewed pipeline with
# *approximate normalization* (arxiv 2408.11997 — the serve engine's "bulk"
# tier, core/chained_fma.approx_*). The coarse LZA drops the low bits of the
# count tree and the fine stages of every per-PE normalize∥align shifter —
# the barrel shifter is the dominant mux structure in the FMA add path — so
# the design gives back more area/power than the skew's forwarding registers
# cost. Timing is identical to SKEWED (1 cycle/row; the shift still happens,
# just quantized), so only the energy constants change.
SKEWED_APPROX = "skewed_approx"

# Paper §IV synthesis constants (relative to baseline); SKEWED_APPROX values
# are modeled from the 2408.11997 shifter/LZA reductions, not synthesized.
REL_AREA = {BASELINE: 1.00, SKEWED: 1.09, SKEWED_APPROX: 0.99}
REL_POWER = {BASELINE: 1.00, SKEWED: 1.07, SKEWED_APPROX: 0.97}

# Absolute anchors for reporting (per-PE, representative of a 45nm bf16 FMA
# at 1 GHz; only *ratios* matter for the paper's claims).
BASE_PE_POWER_MW = 1.9
BASE_PE_AREA_UM2 = 3600.0

# Two-component power split: a per-cycle component (clock tree, pipeline
# registers, leakage — scales with *area*, burns for every cycle the array is
# busy) and a per-MAC component (datapath switching — scales with useful work,
# which is identical for both designs, but each skewed MAC costs the fix-logic
# overhead). Register/clock power dominates dense SAs; 0.85/0.15 reproduces
# the paper's measured energy within ~1 % (see EXPERIMENTS.md §Paper-claims).
CYCLE_POWER_SHARE = 0.85
MAC_POWER_SHARE = 1.0 - CYCLE_POWER_SHARE
REL_MAC_ENERGY = {BASELINE: 1.00, SKEWED: 1.07, SKEWED_APPROX: 0.93}


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    layer: str
    cycles_base: int
    cycles_skew: int
    energy_base: float  # µJ
    energy_skew: float  # µJ

    @property
    def latency_saving(self) -> float:
        return 1.0 - self.cycles_skew / self.cycles_base if self.cycles_base else 0.0

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_skew / self.energy_base if self.energy_base else 0.0


def array_power_w(sa: SAConfig) -> float:
    return REL_POWER[sa.pipeline] * BASE_PE_POWER_MW * 1e-3 * sa.rows * sa.cols


def array_area_mm2(sa: SAConfig) -> float:
    return REL_AREA[sa.pipeline] * BASE_PE_AREA_UM2 * 1e-6 * sa.rows * sa.cols


def layer_energy_uj(layer, sa: SAConfig, dw_mode: str = "packed") -> float:
    """E = per-cycle power × latency + per-MAC energy × MAC count."""
    cycles = wl.layer_latency(layer, sa, dw_mode)
    macs = wl.layer_macs(layer, sa.rows, dw_mode)
    p0 = BASE_PE_POWER_MW * 1e-3 * sa.rows * sa.cols        # W at full tilt
    e_cycle = (CYCLE_POWER_SHARE * p0 * REL_AREA[sa.pipeline]
               * cycles / (sa.freq_ghz * 1e9))
    # per-MAC energy anchored so that a fully-utilized baseline array splits
    # power 85/15 between the two components
    e_per_mac = MAC_POWER_SHARE * BASE_PE_POWER_MW * 1e-3 / (sa.freq_ghz * 1e9)
    e_mac = REL_MAC_ENERGY[sa.pipeline] * e_per_mac * macs
    return (e_cycle + e_mac) * 1e6


def network_report(name: str, rows: int = 128, cols: int = 128,
                   dw_mode: str = "packed") -> list[EnergyReport]:
    """Per-layer baseline-vs-skewed energy (the data behind Figs. 7/8)."""
    base = SAConfig(rows, cols, pipeline=BASELINE)
    skew = SAConfig(rows, cols, pipeline=SKEWED)
    out = []
    for layer in wl.WORKLOADS[name]():
        cb = wl.layer_latency(layer, base, dw_mode)
        cs = wl.layer_latency(layer, skew, dw_mode)
        out.append(EnergyReport(
            layer=layer.name, cycles_base=cb, cycles_skew=cs,
            energy_base=layer_energy_uj(layer, base, dw_mode),
            energy_skew=layer_energy_uj(layer, skew, dw_mode)))
    return out


def network_totals(name: str, rows: int = 128, cols: int = 128,
                   dw_mode: str = "packed") -> dict:
    reps = network_report(name, rows, cols, dw_mode)
    cb = sum(r.cycles_base for r in reps)
    cs = sum(r.cycles_skew for r in reps)
    eb = sum(r.energy_base for r in reps)
    es = sum(r.energy_skew for r in reps)
    return {
        "network": name, "dw_mode": dw_mode,
        "cycles_base": cb, "cycles_skew": cs,
        # a workload whose layers all degenerate to zero cycles/energy (e.g.
        # every dim rounds to 0 under an aggressive dw_mode) reports 0.0
        # saving, not ZeroDivisionError
        "latency_saving": 1 - cs / cb if cb else 0.0,
        "energy_base_uj": eb, "energy_skew_uj": es,
        "energy_saving": 1 - es / eb if eb else 0.0,
    }


# ---------------------------------------------------------------------------
# Serving-tier energy: per-token decode energy by datapath design
# ---------------------------------------------------------------------------

# Which SA design each serve datapath mode runs on (serve/engine.py chunks).
MODE_DESIGN = {"exact": SKEWED, "approx": SKEWED_APPROX}


def decode_token_energy_uj(macs_per_token: int, design: str = SKEWED,
                           freq_ghz: float = 1.0,
                           utilization: float = 1.0) -> float:
    """Modeled energy (µJ) to decode one token on an SA of `design`.

    Same two-component split as `layer_energy_uj`, expressed per token:
    busy cycles = macs / (rows · cols · utilization), so the array size
    cancels and only `utilization` (PE occupancy of the decode GEMMs —
    low at small batch, where fill time dominates) scales the per-cycle
    component. Ratios between designs are the meaningful output."""
    if macs_per_token <= 0:
        return 0.0
    base_w = BASE_PE_POWER_MW * 1e-3
    hz = freq_ghz * 1e9
    e_cycle = (CYCLE_POWER_SHARE * base_w * REL_AREA[design]
               * macs_per_token / (max(utilization, 1e-9) * hz))
    e_mac = (MAC_POWER_SHARE * base_w * REL_MAC_ENERGY[design]
             * macs_per_token / hz)
    return (e_cycle + e_mac) * 1e6


def tier_energy_summary(tier_mode_tokens: dict, macs_per_token: int,
                        freq_ghz: float = 1.0,
                        utilization: float = 1.0) -> dict:
    """Per-tier modeled decode energy for a served request stream.

    `tier_mode_tokens` is the scheduler's real-token accounting
    ({(tier, mode): tokens} or the summary's {"tier/mode": tokens}):
    tokens decoded on the approximate datapath are charged SKEWED_APPROX
    energy, everything else (premium, and bulk tokens that shared a chunk
    with premium) honest exact-datapath energy. Reports the saving vs
    running the identical stream all-exact."""
    counts: dict[tuple[str, str], int] = {}
    for key, n in tier_mode_tokens.items():
        tier, mode = key.split("/") if isinstance(key, str) else key
        counts[(tier, mode)] = counts.get((tier, mode), 0) + int(n)
    e_tok = {m: decode_token_energy_uj(macs_per_token, d, freq_ghz,
                                       utilization)
             for m, d in MODE_DESIGN.items()}
    per_tier: dict[str, float] = {}
    total = exact_total = 0.0
    tokens = 0
    for (tier, mode), n in sorted(counts.items()):
        e = n * e_tok[mode]
        per_tier[tier] = per_tier.get(tier, 0.0) + e
        total += e
        exact_total += n * e_tok["exact"]
        tokens += n
    return {
        "tokens": tokens,
        "energy_uj": round(total, 3),
        "energy_uj_all_exact": round(exact_total, 3),
        "energy_saving": round(1 - total / exact_total, 4)
        if exact_total else 0.0,
        "tier_energy_uj": {t: round(e, 3)
                           for t, e in sorted(per_tier.items())},
        "token_energy_uj": {m: round(e, 6) for m, e in sorted(e_tok.items())},
    }
