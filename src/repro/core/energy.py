"""Area / power / energy model for the two SA pipeline designs (paper §IV).

The paper's synthesis results (Catapult HLS → Oasys, 45 nm, 1 GHz, 128×128
PEs, Bfloat16 inputs / FP32 reduction, power via PowerPro):

  * skewed design area  = 1.09 × baseline  (extra pipeline registers for the
    intermediate ê / LZA forwards + the exponent-fix logic)
  * skewed design power = 1.07 × baseline  (average, across CNN layers)

Energy per layer is `power × latency`; the paper's headline result is that the
skew's latency savings amortize its power overhead: per-layer energy *rises*
for early CNN layers (M ≫ array fill time ⇒ tiny latency gain < 7 % power
cost) and *falls* sharply for late layers (small spatial M, many K/N tiles ⇒
the 2R→R fill saving dominates) — Figs. 7 & 8 — netting −8 % (MobileNet) /
−11 % (ResNet50) total energy.
"""
from __future__ import annotations

import dataclasses

from .systolic import BASELINE, SKEWED, SAConfig
from . import workloads as wl

# Paper §IV synthesis constants (relative to baseline).
REL_AREA = {BASELINE: 1.00, SKEWED: 1.09}
REL_POWER = {BASELINE: 1.00, SKEWED: 1.07}

# Absolute anchors for reporting (per-PE, representative of a 45nm bf16 FMA
# at 1 GHz; only *ratios* matter for the paper's claims).
BASE_PE_POWER_MW = 1.9
BASE_PE_AREA_UM2 = 3600.0

# Two-component power split: a per-cycle component (clock tree, pipeline
# registers, leakage — scales with *area*, burns for every cycle the array is
# busy) and a per-MAC component (datapath switching — scales with useful work,
# which is identical for both designs, but each skewed MAC costs the fix-logic
# overhead). Register/clock power dominates dense SAs; 0.85/0.15 reproduces
# the paper's measured energy within ~1 % (see EXPERIMENTS.md §Paper-claims).
CYCLE_POWER_SHARE = 0.85
MAC_POWER_SHARE = 1.0 - CYCLE_POWER_SHARE
REL_MAC_ENERGY = {BASELINE: 1.00, SKEWED: 1.07}


@dataclasses.dataclass(frozen=True)
class EnergyReport:
    layer: str
    cycles_base: int
    cycles_skew: int
    energy_base: float  # µJ
    energy_skew: float  # µJ

    @property
    def latency_saving(self) -> float:
        return 1.0 - self.cycles_skew / self.cycles_base if self.cycles_base else 0.0

    @property
    def energy_saving(self) -> float:
        return 1.0 - self.energy_skew / self.energy_base if self.energy_base else 0.0


def array_power_w(sa: SAConfig) -> float:
    return REL_POWER[sa.pipeline] * BASE_PE_POWER_MW * 1e-3 * sa.rows * sa.cols


def array_area_mm2(sa: SAConfig) -> float:
    return REL_AREA[sa.pipeline] * BASE_PE_AREA_UM2 * 1e-6 * sa.rows * sa.cols


def layer_energy_uj(layer, sa: SAConfig, dw_mode: str = "packed") -> float:
    """E = per-cycle power × latency + per-MAC energy × MAC count."""
    cycles = wl.layer_latency(layer, sa, dw_mode)
    macs = wl.layer_macs(layer, sa.rows, dw_mode)
    p0 = BASE_PE_POWER_MW * 1e-3 * sa.rows * sa.cols        # W at full tilt
    e_cycle = CYCLE_POWER_SHARE * p0 * REL_AREA[sa.pipeline] \
        * cycles / (sa.freq_ghz * 1e9)
    # per-MAC energy anchored so that a fully-utilized baseline array splits
    # power 85/15 between the two components
    e_per_mac = MAC_POWER_SHARE * BASE_PE_POWER_MW * 1e-3 / (sa.freq_ghz * 1e9)
    e_mac = REL_MAC_ENERGY[sa.pipeline] * e_per_mac * macs
    return (e_cycle + e_mac) * 1e6


def network_report(name: str, rows: int = 128, cols: int = 128,
                   dw_mode: str = "packed") -> list[EnergyReport]:
    """Per-layer baseline-vs-skewed energy (the data behind Figs. 7/8)."""
    base = SAConfig(rows, cols, pipeline=BASELINE)
    skew = SAConfig(rows, cols, pipeline=SKEWED)
    out = []
    for layer in wl.WORKLOADS[name]():
        cb = wl.layer_latency(layer, base, dw_mode)
        cs = wl.layer_latency(layer, skew, dw_mode)
        out.append(EnergyReport(
            layer=layer.name, cycles_base=cb, cycles_skew=cs,
            energy_base=layer_energy_uj(layer, base, dw_mode),
            energy_skew=layer_energy_uj(layer, skew, dw_mode)))
    return out


def network_totals(name: str, rows: int = 128, cols: int = 128,
                   dw_mode: str = "packed") -> dict:
    reps = network_report(name, rows, cols, dw_mode)
    cb = sum(r.cycles_base for r in reps)
    cs = sum(r.cycles_skew for r in reps)
    eb = sum(r.energy_base for r in reps)
    es = sum(r.energy_skew for r in reps)
    return {
        "network": name, "dw_mode": dw_mode,
        "cycles_base": cb, "cycles_skew": cs,
        "latency_saving": 1 - cs / cb,
        "energy_base_uj": eb, "energy_skew_uj": es,
        "energy_saving": 1 - es / eb,
    }
