"""Beyond-paper performance optimizations (EXPERIMENTS.md §Perf).

Each flag gates one hillclimb change so baseline/optimized lowerings can be
A/B'd from the same tree. `REPRO_OPT=0` disables all.

  pad_kv_heads        — pad KV heads (and the grouped Q heads) up to the TP
                        axis size when KVH doesn't divide it. Without this
                        the SPMD partitioner REPLICATES all attention einsums
                        across the model axis (observed: 16× attention FLOPs
                        on phi3 40H/10KVH, full KV-cache reshard per decode
                        step on gemma). Padding costs ≤2× score FLOPs but
                        shards 16×.
  bf16_params_in_layers — cast layer params to bf16 at superblock entry, so
                        FSDP all-gathers move bf16, not fp32 (2× ICI saving
                        on llama4-maverick). Numerically identical: sa_dot
                        quantizes to bf16 at every use anyway.
  pallas_attention    — route forward-only attention (serving prefill) through
                        the Pallas flash kernel (kernels/sa_attention.py):
                        softmax state stays in VMEM instead of materializing
                        probability tiles in HBM. Default on for TPU only
                        (interpret mode on CPU is correctness-grade, not
                        speed-grade); training keeps the custom-VJP jnp path.
  moe_dropless_serve  — route MoE through the dropless dense dispatch
                        (models/moe.py moe_ffn_dropless) whenever a decode
                        cache is threaded through the forward. Capacity-drop
                        dispatch silently drops overflow tokens — fine as a
                        training approximation, unacceptable when serving a
                        user's prompt, and it breaks prefill+decode ≡ full
                        forward exactness. Costs E/k× MoE FLOPs at decode
                        shapes (T ∈ {1..8}), where the GEMMs are latency-
                        not throughput-bound. Unlike the perf flags this is
                        a correctness switch, so REPRO_OPT=0 does NOT
                        disable it.
  fused_epilogue      — fuse bias add / activation into the GEMM epilogue
                        (models/layers.py passes bias=/act= to sa_dot). On the
                        pallas backend this runs inside the kernel's final K
                        step before the single output rounding; on xla it is
                        the same fp32 math before cast_out — so the flag is
                        numerics-preserving under the default fp32 output
                        format and A/B-s only the fusion, not the result.

The GEMM backend itself (xla | pallas | emulate) is a string knob, not a
bool flag: `gemm_backend()` reads REPRO_GEMM_BACKEND (default "xla") and
seeds `core.precision.DEFAULT_POLICY`, so the whole stack — layers, train
step, benchmarks — is A/B-able end-to-end from one environment variable.
`decode_attn_impl()` (REPRO_DECODE_ATTN, default "fused") is the same
pattern for the paged decode-attention path: fused Pallas page walk vs the
gather+dense fallback.
"""
from __future__ import annotations

import os

import jax

_ENABLED = os.environ.get("REPRO_OPT", "1") not in ("0", "false", "off")

FLAGS = {
    "pad_kv_heads": _ENABLED,
    "bf16_params_in_layers": _ENABLED,
    "pallas_attention": _ENABLED and jax.default_backend() == "tpu",
    "fused_epilogue": _ENABLED,
    # NOT gated on REPRO_OPT: serving exactness is a correctness property,
    # not a perf optimization — the kill-switch must never silently revert
    # to token-dropping dispatch. A/B via set_flag / moe_ffn(dropless=).
    "moe_dropless_serve": True,
    # REFUTED (kept for the record, default off): padding the expert dim at
    # trace time (granite 40→48) forces a per-layer-per-µstep reshard of the
    # F-sharded stored weights into the E-sharded compute layout — measured
    # +104 % collectives (10.9 s→22.3 s) instead of the predicted win. The
    # correct version stores params E-padded (checkpoint-shape change);
    # documented in EXPERIMENTS.md §Perf.
    "pad_experts": False,
}


def enabled(name: str) -> bool:
    return FLAGS.get(name, False)


def set_flag(name: str, value: bool):
    FLAGS[name] = value


_GEMM_BACKENDS = ("xla", "pallas", "emulate")


def gemm_backend() -> str:
    """Process-default GEMM backend for `PrecisionPolicy` (reads
    REPRO_GEMM_BACKEND at call time; `core.precision.current_policy`
    consults this on every un-scoped call, so late env changes are
    honored). Scoped overrides go through `core.precision.use_policy`."""
    backend = os.environ.get("REPRO_GEMM_BACKEND", "xla")
    if backend not in _GEMM_BACKENDS:
        raise ValueError(
            f"REPRO_GEMM_BACKEND={backend!r}; want one of {_GEMM_BACKENDS}")
    return backend


_DECODE_ATTN_IMPLS = ("fused", "gather")


def decode_attn_impl() -> str:
    """Paged decode-attention implementation (reads REPRO_DECODE_ATTN at
    call time, same contract as `gemm_backend`). "fused" (default) walks
    the block table inside the Pallas kernel
    (kernels/sa_decode_attention.py); "gather" is the A/B fallback that
    materializes the dense gathered view and runs jnp `decode_attention`
    on top — kept exactly like REPRO_KV=ring keeps the dense ring. The two
    are pinned bit-identical (tests/test_decode_kernel.py), so the knob
    A/Bs only the data movement. Consulted at trace time in
    models/layers.py; policies the kernel can't reproduce (FP8 inputs,
    non-fp32 output rounding) fall back to "gather" regardless."""
    impl = os.environ.get("REPRO_DECODE_ATTN", "fused")
    if impl not in _DECODE_ATTN_IMPLS:
        raise ValueError(
            f"REPRO_DECODE_ATTN={impl!r}; want one of {_DECODE_ATTN_IMPLS}")
    return impl


def prefix_cache_enabled() -> bool:
    """Prefix sharing + copy-on-write on the paged KV (reads
    REPRO_PREFIX_CACHE at call time, default on). When on, paged serve
    engines key whole-page prompt-prefix runs by
    (config fingerprint, tier, token ids) and map cache hits into new
    block tables with refcount bumps instead of re-prefilling
    (serve/scheduler.PageAllocator). "0" falls back to the allocate-and-
    prefill-everything path — kept as an A/B exactly like
    REPRO_DECODE_ATTN=gather; the two are pinned token-identical in
    tests/test_paged_kv.py. Engines additionally auto-disable sharing for
    layouts where a page is not a pure function of the prompt (local-
    window dense rings, ssm/hybrid states)."""
    return os.environ.get("REPRO_PREFIX_CACHE", "1") not in (
        "0", "false", "off")


def disagg_enabled() -> bool:
    """Disaggregated prefill/decode serving (reads REPRO_DISAGG at call
    time, default off — opt-in, same contract as `prefix_cache_enabled`).
    When on, paged serve engines split into a prefill pool and a decode
    pool with an explicit KV-page handoff (DESIGN.md §10): prefill workers
    run dense batch-1 prefill into a staging fragment, the finished pages
    are scattered whole into the shared pool, and decode admissions drain
    a ready queue of already-prefilled requests between chunks — decode
    never waits on prefill compute, only on the handoff splice. "1" and
    "0" are pinned token-identical on the greedy stream digest (CI
    serve-smoke), so the knob trades scheduling only, never tokens.
    Engines auto-disable the split where pages are not a pure function of
    the prompt (ring layout, local-window rings, ssm/hybrid state) — the
    same gate family as prefix sharing."""
    return os.environ.get("REPRO_DISAGG", "0") not in ("0", "false", "off")


def prefill_bucket_enabled() -> bool:
    """Prompt-length bucketing in the serve prefill path (reads
    REPRO_PREFILL_BUCKET at call time, default off). When on, attention-
    only engines pad each prefill's token block up to a powers-of-two-ish
    bucket length, so mixed --prompt-lens streams reuse a handful of jit
    traces instead of retracing per distinct length (the summary's
    `prefill_compiles` counts distinct traces). Padded rows are masked
    after the fact: their cache positions are forced to -1 (invisible to
    the attention mask, exactly like empty ring entries) and the logits
    are taken at the real last token via `last_index`, so real rows come
    out of the same causal arithmetic. Engines auto-disable bucketing for
    layouts where padded writes could touch live state (local-window
    rings, ssm/hybrid recurrence) — right-padding a recurrence advances
    it through garbage tokens."""
    return os.environ.get("REPRO_PREFILL_BUCKET", "0") not in (
        "0", "false", "off")


def spec_decode_enabled() -> bool:
    """Self-speculative decoding kill-switch (reads REPRO_SPEC_DECODE at
    call time, default on — same contract as `prefix_cache_enabled`).
    The flag only *arms* the path: a serve engine actually drafts when its
    `spec_k >= 1` (constructor arg or REPRO_SPEC_K / --spec-k), so default
    environments never speculate. "0" is the A/B: under greedy decoding
    the spec path is pinned token-identical to plain chunked decode
    (tests/test_serve.py, CI serve-smoke), so the switch trades wall time
    only, never tokens. Engines additionally auto-disable drafting where
    rollback-by-position is unsound (ssm/hybrid recurrent state, single-
    superblock stacks with nothing to early-exit from)."""
    return os.environ.get("REPRO_SPEC_DECODE", "1") not in (
        "0", "false", "off")


def spec_k(default: int = 0) -> int:
    """Default draft length for self-speculative decoding (reads
    REPRO_SPEC_K at call time; 0 = off). Each serve iteration drafts
    `spec_k` tokens with the early-exit forward and verifies them in one
    batched M = spec_k+1 forward; `ServeEngine(spec_k=...)` and the
    driver's --spec-k override this per engine."""
    k = int(os.environ.get("REPRO_SPEC_K", default))
    if k < 0:
        raise ValueError(f"REPRO_SPEC_K={k}; want >= 0")
    return k


_SA_MODES = ("exact", "approx")


def sa_mode() -> str:
    """Process-default SA arithmetic mode for `PrecisionPolicy` (reads
    REPRO_SA_MODE at call time, same contract as `gemm_backend`).
    "exact" is the paper's round-once datapath; "approx" is the
    approximate-normalization variant (coarse LZA, arxiv 2408.11997) that
    backs the serve engine's "bulk" quality tier."""
    mode = os.environ.get("REPRO_SA_MODE", "exact")
    if mode not in _SA_MODES:
        raise ValueError(f"REPRO_SA_MODE={mode!r}; want one of {_SA_MODES}")
    return mode
