"""Reduced-precision floating-point formats (paper Fig. 1).

The paper targets Bfloat16 inputs with FP32 column reduction, and motivates the
skewed pipeline with the FP8 formats of Micikevicius et al. (E4M3 / E5M2), whose
mantissa fields are *narrower than* their exponent fields — the delay-profile flip
that makes the exponent path co-critical.

This module gives each format a first-class descriptor plus JAX-traceable
encode/decode/quantize helpers used by

  * ``core.chained_fma``   — the bit-exact datapath models (field extraction),
  * ``core.precision``     — the framework-wide GEMM precision policy,
  * ``kernels/quantize.py``— the Pallas quantization kernels.

Conventions (match the paper's hardware assumptions, documented in DESIGN.md):
  * subnormals are flushed to zero (FTZ) on encode — standard for DL accelerators,
  * saturating overflow (no Inf) for FP8 per the E4M3 convention; E5M2 keeps Inf,
  * round-to-nearest-even everywhere a rounding step exists (i.e. only at the
    column end / output write-back — never inside the chained accumulation).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class FPFormat:
    """A sign/exponent/mantissa floating-point format descriptor."""

    name: str
    exp_bits: int
    man_bits: int          # stored (fraction) bits, excluding hidden bit
    saturate: bool = False  # True => clamp to max finite instead of Inf

    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def emax(self) -> int:
        # E4M3 (OCP FP8) reclaims the top exponent for finite values.
        if self.name == "fp8_e4m3":
            return (1 << self.exp_bits) - 1 - self.bias
        return (1 << self.exp_bits) - 2 - self.bias

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def max_finite(self) -> float:
        if self.name == "fp8_e4m3":
            # 1.110 x 2^8 = 448 (mantissa 0b111 with the NaN row excluded)
            return float((2.0 - 2.0 ** (-self.man_bits) * 2) * 2.0 ** self.emax)
        return float((2.0 - 2.0 ** (-self.man_bits)) * 2.0 ** self.emax)

    @property
    def min_normal(self) -> float:
        return float(2.0 ** self.emin)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"FPFormat({self.name}: 1/{self.exp_bits}/{self.man_bits})"


FP32 = FPFormat("fp32", exp_bits=8, man_bits=23)
BF16 = FPFormat("bf16", exp_bits=8, man_bits=7)
FP16 = FPFormat("fp16", exp_bits=5, man_bits=10)
FP8_E4M3 = FPFormat("fp8_e4m3", exp_bits=4, man_bits=3, saturate=True)
FP8_E5M2 = FPFormat("fp8_e5m2", exp_bits=5, man_bits=2)

FORMATS: dict[str, FPFormat] = {
    f.name: f for f in (FP32, BF16, FP16, FP8_E4M3, FP8_E5M2)
}


def get_format(name: str | FPFormat) -> FPFormat:
    if isinstance(name, FPFormat):
        return name
    try:
        return FORMATS[name]
    except KeyError as e:
        raise ValueError(f"unknown FP format {name!r}; have {sorted(FORMATS)}") from e


# ---------------------------------------------------------------------------
# Field extraction / packing (numpy + jnp, used by the bit-exact datapath)
# ---------------------------------------------------------------------------

def decompose(x, fmt: FPFormat):
    """Split values into integer (sign, exponent, mantissa-with-hidden-bit).

    Returns (s, e, m) where the represented value is
    ``(-1)^s * m * 2^(e - bias - man_bits)`` and m includes the hidden bit
    (m == 0 encodes zero; FTZ applied). Works on jnp or np arrays.
    """
    xnp = jnp if isinstance(x, jax.Array) else np
    f32 = xnp.asarray(x, dtype=xnp.float32)
    bits = (f32.view(xnp.uint32).astype(xnp.int64) if xnp is np else
            jax.lax.bitcast_convert_type(f32, jnp.uint32).astype(jnp.int64))
    s = (bits >> 31) & 0x1
    e32 = (bits >> 23) & 0xFF
    m32 = bits & 0x7FFFFF
    # re-bias into the target format and truncate mantissa (no rounding here —
    # decompose() is used on values already representable in `fmt`).
    shift = 23 - fmt.man_bits
    m = (m32 >> shift) | (xnp.where(e32 > 0, 1, 0) << fmt.man_bits)
    e = e32 - 127 + fmt.bias
    zero = (e32 == 0)  # FTZ: subnormal f32 treated as zero
    m = xnp.where(zero, 0, m)
    e = xnp.where(zero, 0, e)
    return s.astype(xnp.int32), e.astype(xnp.int32), m.astype(xnp.int64)


def compose(s, e, m, fmt: FPFormat):
    """Inverse of :func:`decompose` — rebuild float32 from integer fields."""
    xnp = jnp if isinstance(m, jax.Array) else np
    s = xnp.asarray(s, dtype=xnp.int64)
    e = xnp.asarray(e, dtype=xnp.int64)
    m = xnp.asarray(m, dtype=xnp.int64)
    value = m.astype(xnp.float64) * (2.0 ** (e - fmt.bias - fmt.man_bits).astype(xnp.float64))
    value = xnp.where(m == 0, 0.0, value)
    return (xnp.where(s == 1, -value, value)).astype(xnp.float32)


# ---------------------------------------------------------------------------
# Quantization (JAX-traceable; round-to-nearest-even, FTZ, saturating)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("fmt_name",))
def _quantize_jit(x: jax.Array, fmt_name: str) -> jax.Array:
    fmt = get_format(fmt_name)
    if fmt.name == "fp32":
        return x.astype(jnp.float32)
    if fmt.name in ("bf16", "fp16"):
        dt = jnp.bfloat16 if fmt.name == "bf16" else jnp.float16
        y = x.astype(dt).astype(jnp.float32)
        # FTZ: the IEEE cast keeps subnormals, the SA datapath does not
        return jnp.where(jnp.abs(y) < fmt.min_normal, 0.0, y)
    # Generic path (FP8): round f32 to `man_bits` mantissa bits (RNE) by masking
    # in the integer domain, then clamp exponent range with FTZ + saturation.
    f32 = x.astype(jnp.float32)
    bits = jax.lax.bitcast_convert_type(f32, jnp.uint32)
    shift = 23 - fmt.man_bits
    half = jnp.uint32(1 << (shift - 1))
    lsb = (bits >> shift) & 1
    rounded = bits + half - 1 + lsb  # RNE on the mantissa field
    rounded = rounded & ~jnp.uint32((1 << shift) - 1)
    y = jax.lax.bitcast_convert_type(rounded, jnp.float32)
    # clamp: FTZ below min_normal, saturate/inf above max_finite
    ay = jnp.abs(y)
    y = jnp.where(ay < fmt.min_normal, 0.0, y)
    if fmt.saturate:
        y = jnp.clip(y, -fmt.max_finite, fmt.max_finite)
    else:
        y = jnp.where(ay > fmt.max_finite, jnp.sign(y) * jnp.inf, y)
    return jnp.where(jnp.isnan(f32), f32, y)


def quantize(x, fmt: str | FPFormat) -> jax.Array:
    """Quantize to the target reduced-precision format, returned as float32."""
    return _quantize_jit(jnp.asarray(x), get_format(fmt).name)


def quantize_np(x: np.ndarray, fmt: str | FPFormat) -> np.ndarray:
    """Numpy twin of :func:`quantize` (used by pure-numpy oracles)."""
    return np.array(quantize(jnp.asarray(np.asarray(x, np.float32)), fmt))


def representable(rng: np.random.Generator, shape, fmt: str | FPFormat,
                  scale: float = 1.0) -> np.ndarray:
    """Random values exactly representable in `fmt` (for bit-exact tests)."""
    f = get_format(fmt)
    x = rng.standard_normal(shape).astype(np.float32) * scale
    return quantize_np(x, f)
