"""Core: the paper's contribution — reduced-precision SA arithmetic with
skewed pipelines — plus the models that reproduce its claims."""
from .fpformats import (BF16, FP8_E4M3, FP8_E5M2, FP16, FP32, FORMATS,
                        FPFormat, get_format, quantize)
from .precision import PrecisionPolicy, DEFAULT_POLICY, sa_dot, sa_einsum, use_policy
from .systolic import BASELINE, SKEWED, SAConfig, gemm_latency, speedup

__all__ = [
    "BF16", "FP8_E4M3", "FP8_E5M2", "FP16", "FP32", "FORMATS", "FPFormat",
    "get_format", "quantize", "PrecisionPolicy", "DEFAULT_POLICY", "sa_dot",
    "sa_einsum", "use_policy", "BASELINE", "SKEWED", "SAConfig",
    "gemm_latency", "speedup",
]
