"""The paper's arithmetic contract as the framework-wide GEMM entry point.

Every matrix multiplication in this framework goes through :func:`sa_dot`,
which enforces the systolic-array datapath semantics of the paper (§II):

  * inputs quantized to a reduced-precision format (Bfloat16 / FP8),
  * products chained-accumulated in double width (FP32) with **no
    intermediate normalization/rounding**,
  * one rounding at the end of the reduction ("south end of the column").

Backends:
  * ``xla``     — `lax.dot_general` with `preferred_element_type=float32`.
                  On TPU this lowers straight onto the MXU, whose hardware
                  accumulate implements exactly the above contract.
  * ``pallas``  — our tiled Pallas kernel (`repro.kernels.ops.sa_matmul`):
                  explicit K-loop with a persistent unnormalized fp32 VMEM
                  accumulator — the software restatement of the skewed
                  column (see DESIGN.md §2b).
  * ``emulate`` — the bit-exact integer-field datapath of
                  :mod:`repro.core.chained_fma` (tiny shapes; validation).

The policy also selects the *output* rounding target, mirroring where the
paper's single rounder sits.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .fpformats import get_format, quantize

_JNP_INPUT_DTYPE = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
    # FP8 storage dtypes exist in jnp; CPU backends may not support matmul on
    # them, so the fp8 paths quantize values but carry them in bf16 containers
    # ("fake quant", numerically faithful to Fig. 1's formats).
    "fp8_e4m3": jnp.bfloat16,
    "fp8_e5m2": jnp.bfloat16,
}

# The XLA *CPU* runtime cannot execute batched bf16×bf16→f32 dots. Since every
# reduced-format value is exactly representable in f32 and products of ≤12-bit
# significands are exact in f32, carrying quantized values in f32 containers
# is BIT-IDENTICAL to the bf16 MXU contract — so CPU execution flips this flag
# on. The dry-run (lower/compile only, never executes) flips it off to lower
# the TPU-true bf16 program so cost_analysis sees real bf16 byte counts.
EXACT_CPU_CONTAINERS = jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """What the SA does to a GEMM: formats + backend."""

    input_format: str = "bf16"       # paper's evaluated configuration
    accum_format: str = "fp32"       # "double-width reduction"
    output_format: str = "fp32"      # rounding target at the column end
    backend: str = "xla"             # xla | pallas | emulate
    mode: str = "exact"              # exact | approx (bulk-tier coarse LZA)

    def __post_init__(self):
        get_format(self.input_format)
        if self.accum_format != "fp32":
            raise ValueError("the SA reduces in FP32 (paper §II)")
        if self.backend not in ("xla", "pallas", "emulate"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.mode not in ("exact", "approx"):
            raise ValueError(f"unknown SA mode {self.mode!r}")

    def cast_in(self, x: jax.Array) -> jax.Array:
        fmt = get_format(self.input_format)
        if fmt.name == "fp32":
            return x.astype(jnp.float32)
        if fmt.name in ("bf16", "fp16"):
            q = x.astype(_JNP_INPUT_DTYPE[fmt.name])
            return q.astype(jnp.float32) if EXACT_CPU_CONTAINERS else q
        # fp8: quantize values to the format's grid, carry in bf16 (exact
        # container: bf16 has 8 exponent / 7 mantissa bits ≥ any FP8 format).
        q = quantize(x, fmt)
        return q if EXACT_CPU_CONTAINERS else q.astype(jnp.bfloat16)

    def cast_out(self, y: jax.Array) -> jax.Array:
        fmt = get_format(self.output_format)
        if fmt.name == "fp32":
            return y.astype(jnp.float32)
        return quantize(y, fmt)


# Default backend/mode are A/B-able from one knob each (core/optflags.py
# reads REPRO_GEMM_BACKEND and REPRO_SA_MODE) without touching call sites.
from .optflags import gemm_backend as _default_backend  # noqa: E402
from .optflags import sa_mode as _default_mode  # noqa: E402

DEFAULT_POLICY = PrecisionPolicy(backend=_default_backend(),
                                 mode=_default_mode())
_POLICY_STACK: list[PrecisionPolicy] = [DEFAULT_POLICY]


def current_policy() -> PrecisionPolicy:
    # the stack bottom tracks the REPRO_GEMM_BACKEND / REPRO_SA_MODE knobs at
    # call time, so env changes made after import are honored for calls that
    # TRACE after the change (scoped use_policy overrides always win). An
    # already-jitted callable keeps the backend/mode it was traced with — A/B
    # comparisons need a fresh jit wrapper per variant (see
    # tests/test_precision_backends.py and serve/engine.py's per-mode chunks)
    global DEFAULT_POLICY
    if len(_POLICY_STACK) == 1:
        backend, mode = _default_backend(), _default_mode()
        if (backend != _POLICY_STACK[0].backend
                or mode != _POLICY_STACK[0].mode):
            # keep the module-level DEFAULT_POLICY accessor in sync (note:
            # `from repro.core import DEFAULT_POLICY` captures a snapshot)
            DEFAULT_POLICY = _POLICY_STACK[0] = PrecisionPolicy(
                backend=backend, mode=mode)
    return _POLICY_STACK[-1]


class use_policy:
    """Context manager scoping the active precision policy (trace-time)."""

    def __init__(self, policy: PrecisionPolicy):
        self.policy = policy

    def __enter__(self):
        _POLICY_STACK.append(self.policy)
        return self.policy

    def __exit__(self, *exc):
        _POLICY_STACK.pop()


def _emulated_dot(a: jax.Array, w: jax.Array, policy: PrecisionPolicy):
    from .chained_fma import matmul_emulated  # bit-exact numpy model

    pipeline = "approx" if policy.mode == "approx" else "skewed"

    def cb(a_, w_):
        return matmul_emulated(np.asarray(a_), np.asarray(w_),
                               get_format(policy.input_format), pipeline)

    out_shape = jax.ShapeDtypeStruct((a.shape[0], w.shape[1]), jnp.float32)
    return jax.pure_callback(cb, out_shape, a.astype(jnp.float32),
                             w.astype(jnp.float32))


def _epilogue(y: jax.Array, bias, act: str) -> jax.Array:
    """Reference epilogue on the fp32 chain (xla/emulate backends); the
    pallas backend fuses the identical math into its final K step."""
    from repro.kernels.sa_matmul import EPILOGUES, apply_act

    if act not in EPILOGUES:
        # same loud failure the pallas backend gives — a typo'd act must
        # never silently skip the activation on one backend only
        raise ValueError(f"unknown epilogue act {act!r}; have {EPILOGUES}")
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return apply_act(y, act)


def sa_dot(a: jax.Array, w: jax.Array, policy: PrecisionPolicy | None = None,
           precision=None, *, bias: jax.Array | None = None,
           act: str = "none") -> jax.Array:
    """`a @ w` under the SA arithmetic contract. Batched `a` supported.

    `bias`/`act` are the fused epilogue: applied to the fp32 chain *before*
    the single output rounding, on every backend (inside the kernel's final
    K step on pallas; in fp32 before `cast_out` on xla/emulate).

    ``policy.mode == "approx"`` selects the bulk-tier arithmetic on every
    backend: emulate runs the coarse-LZA `approx_chain`, pallas truncates
    the accumulator's guard bits inside the kernel epilogue, and the xla
    fallback applies the same `truncate_mantissa` to the fp32 chain before
    the epilogue — so the tier semantics are backend-independent.
    """
    policy = policy or current_policy()
    a_q, w_q = policy.cast_in(a), policy.cast_in(w)
    if policy.backend == "emulate":
        if a.ndim != 2 or w.ndim != 2:
            raise ValueError("emulate backend supports 2-D GEMMs only")
        y = _emulated_dot(a_q, w_q, policy)
        return policy.cast_out(_epilogue(y, bias, act))
    if policy.backend == "pallas" and a.ndim == 2 and w.ndim == 2:
        from repro.kernels.ops import sa_matmul  # lazy: avoid import cycle

        bias_f32 = None if bias is None else bias.astype(jnp.float32)
        return policy.cast_out(sa_matmul(a_q, w_q, bias=bias_f32, act=act,
                                         mode=policy.mode))
    # xla / fallback: MXU dot with fp32 accumulation, round once on output.
    y = jnp.matmul(a_q, w_q, preferred_element_type=jnp.float32)
    if policy.mode == "approx":
        from repro.kernels.sa_matmul import truncate_mantissa  # lazy: cycle

        y = truncate_mantissa(y)
    return policy.cast_out(_epilogue(y, bias, act))


def sa_einsum(spec: str, a: jax.Array, w: jax.Array,
              policy: PrecisionPolicy | None = None) -> jax.Array:
    """Einsum under the SA contract (attention/MoE paths)."""
    policy = policy or current_policy()
    a_q, w_q = policy.cast_in(a), policy.cast_in(w)
    y = jnp.einsum(spec, a_q, w_q, preferred_element_type=jnp.float32)
    if policy.mode == "approx":
        from repro.kernels.sa_matmul import truncate_mantissa  # lazy: cycle

        y = truncate_mantissa(y)
    return policy.cast_out(y)
