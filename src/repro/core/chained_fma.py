"""Bit-exact models of the chained FP multiply-add datapath in a SA column.

This is the paper's §III, reproduced at the integer-field level:

* ``baseline_*``  — the state-of-the-art 2-stage pipeline of Fig. 3(b): each PE
  receives a *normalized* partial ``(s, e, m)``, aligns, adds, LZA-normalizes
  and forwards the corrected exponent ``e_i = ê_i − L_i``. The dependence of
  PE *i+1*'s exponent-compute on PE *i*'s LZA output is what serializes the
  column (2 cycles / PE — modeled in :mod:`repro.core.systolic`).

* ``skewed_*``    — the proposed pipeline of Fig. 5/6: each PE forwards the
  *unnormalized* pair ``(ê_i, S_i)`` plus the LZA count ``L_i`` one stage
  later. The next PE computes *speculative* values ``d'_{i+1} = |e_M − ê_i|``
  and fixes them with the forwarded ``L_i``:

      d = d' + L_prev              if e_M ≥ ê_prev          (paper, §III.B)
      d = L_prev − d'              if e_M < ê_prev   (sign ⇒ shift direction)

  and the normalization of the incoming sum is *retimed* into the alignment
  shifter (one net shift, left or right — Fig. 6).

The central claim of the paper is that the speculation is **exact** — no
rollback, identical arithmetic results. ``tests/test_chained_fma.py`` proves
``skewed ≡ baseline`` bit-for-bit with hypothesis.

* ``approx_*``    — the cheaper datapath variant of the *approximate
  normalization* FMA (arxiv 2408.11997), modeled on top of the skewed
  interface: the per-PE LZA/normalization shifter is **coarsened** to a
  shift quantum of ``APPROX_COARSE`` bits (only the high bits of the LZA
  count are examined, the fine shifter stages are removed). The forwarded
  count ``L`` is rounded down to a multiple of the quantum, so up to
  ``APPROX_COARSE − 1`` leading zeros stay unnormalized in the wide
  accumulator ("normalization debt"). The value semantics stay exact —
  exponent fix and net shift both consume the same coarsened ``L`` — but
  alignment truncation cuts up to ``APPROX_COARSE − 1`` bits higher per
  step, so results may differ from the exact pipelines **only below the
  guard-bit threshold** (debt ≤ GUARD with the default quantum). The final
  normalization at the column-end rounding stage stays exact, as in the
  real design. This is the arithmetic behind the serve engine's "bulk"
  quality tier (serve/scheduler.py).

Number representation (unbiased exponents, value-anchored):

  normalized    value = (−1)^s · m · 2^(e − P),  msb(m) = P
  unnormalized  value = (−1)^s · S · 2^(ê − Q),  Q = P + 1, msb(S) ≤ Q,
                ê = max(e_M, e_in) + 1,  L = Q − msb(S) ≥ 0,  e = ê − L

``P = ACC_MSB = 26`` gives a 24-bit FP32 significand + ``GUARD = 3`` guard
bits: the "double-width reduction" contract of §II (Bfloat16 in, FP32 down the
column), with truncating alignment (no per-PE rounding) and a single
round-to-nearest-even at the column south end (§II: "rounding is performed
only once, at the South end of each column").
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .fpformats import FPFormat, BF16, get_format, decompose

# Accumulator geometry: 24-bit significand (FP32) + guard bits.
GUARD = 3
ACC_MSB = 23 + GUARD          # P: msb position of a normalized significand
_Q = ACC_MSB + 1              # anchor of unnormalized sums
E_ZERO = -(1 << 20)           # exponent of an exact zero (never wins a max)
_MAXSH = 62                   # clamp shifts (int64-safe; >= register width)

# Approximate-normalization shift quantum (arxiv 2408.11997 model): the LZA
# count is truncated to multiples of this, so normalization debt is bounded
# by APPROX_COARSE − 1 = GUARD bits — per-step truncation error stays inside
# the guard band of the wide accumulator. Power of two (kernel-foldable).
APPROX_COARSE = GUARD + 1


def _msb(x: np.ndarray) -> np.ndarray:
    """Vectorized index of the most significant set bit (-1 for 0)."""
    x = np.asarray(x, dtype=np.int64)
    # exact for x < 2^53: frexp exponent of float64 gives bit-length
    return np.frexp(x.astype(np.float64))[1] - 1


def _shr(x: np.ndarray, n: np.ndarray) -> np.ndarray:
    """Truncating right shift with clamped (always >= 0) shift amount."""
    return np.asarray(x, np.int64) >> np.minimum(np.maximum(n, 0), _MAXSH)


def _shl(x: np.ndarray, n: np.ndarray) -> np.ndarray:
    return np.asarray(x, np.int64) << np.minimum(np.maximum(n, 0), _MAXSH)


def _net_shift(x: np.ndarray, left: np.ndarray) -> np.ndarray:
    """One bidirectional shifter: shift left by `left` (right if negative).

    This is the retimed normalize+align unit of Fig. 6 — the previous PE's
    normalization (≤ L_prev left shifts) and this PE's alignment (right
    shifts) collapse into a single net shift, "as only one of these options
    may occur".
    """
    return np.where(left >= 0, _shl(x, left), _shr(x, -left))


@dataclasses.dataclass
class Normalized:
    """A normalized partial sum (baseline inter-PE interface)."""

    s: np.ndarray  # sign bit
    e: np.ndarray  # unbiased exponent, anchor P (E_ZERO if zero)
    m: np.ndarray  # significand, msb at P (0 if zero)


@dataclasses.dataclass
class Unnormalized:
    """The skewed inter-PE interface: (ê, S) now, L one stage later."""

    s: np.ndarray
    ehat: np.ndarray  # speculative exponent ê (anchor Q = P+1)
    S: np.ndarray     # unnormalized sum, msb ≤ Q
    L: np.ndarray     # LZA count of *this* PE (consumed by next PE's stage 2)


def make_zero(shape) -> Normalized:
    z = np.zeros(shape, dtype=np.int64)
    return Normalized(s=z.copy(), e=np.full(shape, E_ZERO, np.int64), m=z.copy())


def make_zero_unnorm(shape) -> Unnormalized:
    z = np.zeros(shape, dtype=np.int64)
    return Unnormalized(s=z.copy(), ehat=np.full(shape, E_ZERO, np.int64),
                        S=z.copy(), L=z.copy())


# ---------------------------------------------------------------------------
# Stage 1 (both pipelines): the multiplier — exact in the wide accumulator
# ---------------------------------------------------------------------------

def multiply(a: np.ndarray, b: np.ndarray, fmt: FPFormat = BF16) -> Normalized:
    """Exact product of two reduced-precision operands, normalized to P.

    Product of two `man_bits+1`-wide significands is ≤ 2(man_bits+1) bits,
    which fits the P+1 = 27-bit accumulator exactly for every format in
    Fig. 1 — multiplication never rounds (§II: fused, no intermediate
    normalization *of the chain*; the product's own 1-bit normalize is free).
    """
    fmt = get_format(fmt)
    sa, ea, ma = decompose(a, fmt)
    sb, eb, mb = decompose(b, fmt)
    ea = ea.astype(np.int64) - fmt.bias
    eb = eb.astype(np.int64) - fmt.bias
    mm = ma.astype(np.int64) * mb.astype(np.int64)
    msb = _msb(mm)
    e = ea + eb - 2 * fmt.man_bits + msb   # = ea+eb or ea+eb+1
    m = _shl(mm, ACC_MSB - msb)
    zero = mm == 0
    return Normalized(
        s=(sa ^ sb).astype(np.int64),
        e=np.where(zero, E_ZERO, e),
        m=np.where(zero, 0, m),
    )


def _signed_add(s1, m1, s2, m2):
    v = np.where(s1 == 1, -m1, m1) + np.where(s2 == 1, -m2, m2)
    return (v < 0).astype(np.int64), np.abs(v)


# ---------------------------------------------------------------------------
# Baseline PE (Fig. 3(b)): normalize-then-align, corrected exponent forwarded
# ---------------------------------------------------------------------------

def baseline_pe(prod: Normalized, acc: Normalized) -> Normalized:
    """One PE of the reference pipeline. Interface: normalized partials."""
    # exponent compute: ê = max + 1 (anchor Q), d = |e_M − e_{i-1}|
    e_max = np.maximum(prod.e, acc.e)
    d = np.abs(prod.e - acc.e)
    mp = np.where(prod.e >= acc.e, prod.m, _shr(prod.m, d))   # align product
    ma = np.where(acc.e >= prod.e, acc.m, _shr(acc.m, d))     # align partial
    s, S = _signed_add(prod.s, mp, acc.s, ma)
    # LZA + normalize + exponent correction e = ê − L (the stage-2 output on
    # which the *next* PE's stage 1 depends — the serialization of Fig. 4).
    msb = _msb(S)
    L = _Q - msb
    e = (e_max + 1) - L                       # = ê − L
    m = _net_shift(S, L - 1)                  # msb → P (right shift iff carry)
    zero = S == 0
    return Normalized(s=np.where(zero, 0, s),
                      e=np.where(zero, E_ZERO, e),
                      m=np.where(zero, 0, m))


# ---------------------------------------------------------------------------
# Skewed PE (Fig. 5/6): speculative exponent + fix, retimed normalization
# ---------------------------------------------------------------------------

def skewed_pe(prod: Normalized, acc: Unnormalized, *,
              coarse: int = 1) -> Unnormalized:
    """One PE of the proposed pipeline.

    Stage 1 computes speculative ``e' = max(e_M, ê_prev)`` and
    ``d' = |e_M − ê_prev|`` from the *unnormalized* ê of the previous PE
    (its L is not yet available). Stage 2's fix unit receives ``L_prev``
    and corrects, per the paper's case analysis; the incoming sum's
    normalization is folded into the same net shift (Fig. 6).

    ``coarse > 1`` selects the approximate-normalization variant: the LZA
    count this PE forwards is rounded down to a multiple of ``coarse``
    (coarse LZA, quantized shifter — arxiv 2408.11997), leaving up to
    ``coarse − 1`` leading zeros unnormalized in the wide accumulator.
    Because the next PE's exponent fix and net shift consume the same
    coarsened ``L``, the represented value stays consistent; only the
    alignment truncation cutoff rises by the debt.
    """
    ge = prod.e >= acc.ehat            # speculative compare (stage 1)
    d_spec = np.abs(prod.e - acc.ehat)  # d' (stage 1)

    # --- stage-2 fix (uses L_prev, forwarded from the previous PE) --------
    # true normalized exponent of the incoming partial: e_prev = ê − L.
    # paper:  e_M ≥ ê_prev  ⇒ d = d' + L_prev  (product dominates)
    #         e_M <  ê_prev ⇒ d = L_prev − d'  (sign gives the direction)
    d_fix = np.where(ge, d_spec + acc.L, acc.L - d_spec)
    # d_fix > 0  ⇒ product dominates (e_M > e_prev): partial shifts right
    # d_fix <= 0 ⇒ partial dominates: product shifts right by −d_fix
    prod_dom = d_fix > 0
    e_prev = acc.ehat - acc.L
    e_max = np.where(prod_dom, prod.e, e_prev)
    is_zero_prev = acc.S == 0
    e_max = np.where(is_zero_prev, prod.e, e_max)

    # retimed normalize∥align: net left shift of the incoming sum is
    # (L_prev − 1) − max(d_fix, 0) — a single bidirectional shifter.
    acc_net_left = (acc.L - 1) - np.maximum(d_fix, 0)
    Sa = _net_shift(acc.S, acc_net_left)
    mp = _shr(prod.m, np.maximum(-d_fix, 0))
    mp = np.where(prod.e == E_ZERO, 0, mp)
    Sa = np.where(is_zero_prev, 0, Sa)

    s, S = _signed_add(prod.s, mp, acc.s, Sa)
    msb = _msb(S)
    L = _Q - msb
    if coarse > 1:
        L = (L // coarse) * coarse   # coarse LZA: keep only high count bits
    zero = S == 0
    return Unnormalized(
        s=np.where(zero, 0, s),
        ehat=np.where(zero, E_ZERO, e_max + 1),
        S=np.where(zero, 0, S),
        L=np.where(zero, 0, L),
    )


def approx_pe(prod: Normalized, acc: Unnormalized,
              coarse: int = APPROX_COARSE) -> Unnormalized:
    """Approximate-normalization PE (2408.11997): skewed interface with a
    coarse LZA — see :func:`skewed_pe` (``coarse`` > 1)."""
    return skewed_pe(prod, acc, coarse=coarse)


def skewed_finalize(acc: Unnormalized) -> Normalized:
    """The deferred last normalization (§III.B: "the correction for the
    exponent of the last PE ... will happen during the rounding stage at the
    end of the column")."""
    msb = _msb(acc.S)
    L = _Q - msb
    zero = acc.S == 0
    return Normalized(
        s=np.where(zero, 0, acc.s),
        e=np.where(zero, E_ZERO, acc.ehat - L),
        m=np.where(zero, 0, _net_shift(acc.S, L - 1)),
    )


# ---------------------------------------------------------------------------
# Column-end rounding (once per column, §II) and chain runners
# ---------------------------------------------------------------------------

def round_to_f32(r: Normalized) -> np.ndarray:
    """RNE from the P+1-bit accumulator to float32 (the south-edge rounder)."""
    g = GUARD
    low = r.m & ((1 << g) - 1)
    keep = r.m >> g                              # 24-bit significand
    half = 1 << (g - 1)
    round_up = (low > half) | ((low == half) & ((keep & 1) == 1))
    keep = keep + round_up.astype(np.int64)
    # mantissa overflow after rounding: renormalize
    ovf = keep >> 24 != 0
    keep = np.where(ovf, keep >> 1, keep)
    e = r.e + ovf.astype(np.int64)
    # bit-exact f32 construction; FTZ below the normal range, Inf above
    # (matches the fp_emu kernel's output contract exactly).
    e32 = e + 127
    frac = (keep & 0x7FFFFF).astype(np.uint32)
    sgn = (r.s.astype(np.uint32) & 1) << 31
    bits = sgn | (np.clip(e32, 0, 255).astype(np.uint32) << 23) | frac
    bits = np.where(e32 >= 255, sgn | np.uint32(0x7F800000), bits)
    bits = np.where((r.m == 0) | (e32 <= 0), sgn, bits)
    return bits.view(np.float32) if bits.shape else np.uint32(bits).view(np.float32)


def baseline_chain(a: np.ndarray, w: np.ndarray, fmt=BF16) -> np.ndarray:
    """Reference column: psum_i = psum_{i−1} + a_i·w_i, K on axis 0."""
    acc = make_zero(a.shape[1:])
    for k in range(a.shape[0]):
        acc = baseline_pe(multiply(a[k], w[k], fmt), acc)
    return round_to_f32(acc)


def skewed_chain(a: np.ndarray, w: np.ndarray, fmt=BF16) -> np.ndarray:
    """Proposed column, identical arithmetic via the speculative interface."""
    acc = make_zero_unnorm(a.shape[1:])
    for k in range(a.shape[0]):
        acc = skewed_pe(multiply(a[k], w[k], fmt), acc)
    return round_to_f32(skewed_finalize(acc))


def approx_chain(a: np.ndarray, w: np.ndarray, fmt=BF16,
                 coarse: int = APPROX_COARSE) -> np.ndarray:
    """Approximate-normalization column (the "bulk" tier datapath): skewed
    interface, coarse LZA; final normalization at the rounding stage stays
    exact."""
    acc = make_zero_unnorm(a.shape[1:])
    for k in range(a.shape[0]):
        acc = skewed_pe(multiply(a[k], w[k], fmt), acc, coarse=coarse)
    return round_to_f32(skewed_finalize(acc))


_CHAINS = {"baseline": baseline_chain, "skewed": skewed_chain,
           "approx": approx_chain}


def matmul_emulated(a: np.ndarray, w: np.ndarray, fmt=BF16,
                    pipeline: str = "skewed") -> np.ndarray:
    """(M,K) @ (K,N) through the bit-exact SA column model (slow; tests)."""
    a = np.asarray(a, np.float32)
    w = np.asarray(w, np.float32)
    M, K = a.shape
    K2, N = w.shape
    assert K == K2
    if pipeline not in _CHAINS:
        raise ValueError(f"unknown pipeline {pipeline!r}; have {sorted(_CHAINS)}")
    ab = np.broadcast_to(a.T[:, :, None], (K, M, N))       # a[k, m] per (m,n)
    wb = np.broadcast_to(w[:, None, :], (K, M, N))
    return _CHAINS[pipeline](ab, wb, fmt)
