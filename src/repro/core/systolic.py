"""Cycle-accurate timing model of the weight-stationary SA (paper §II–III).

Reproduces the latency behaviour of the two pipeline organizations:

* **baseline** (Fig. 3(b)) — 2-stage FMA per PE; PE *i+1* in a column may only
  start once PE *i* finished both stages (Fig. 4), so the partial sum advances
  one row every **2 cycles**.
* **skewed** (Fig. 6) — speculative exponent forwarding + retimed
  normalization overlap the stages of consecutive PEs, so the partial sum
  advances one row every **1 cycle**, at the cost of one extra trailing add
  stage per column (§III.B, last paragraph).

Both need the single rounding stage at the column south end.

Latency of one (R_used × C_used) weight tile streaming M input rows
(west-to-east input skew of C_used − 1 cycles; one result per cycle once the
pipeline is full):

    baseline: 2·R_used + (C_used − 1) + M + 1(round)
    skewed  :   R_used + (C_used − 1) + M + 1(extra add) + 1(round)

A full GEMM (M×K)·(K×N) tiles K over rows and N over columns of the array;
per-tile weight (re)loads are double-buffered (loading the next tile's
weights overlaps the current tile's compute — standard WS practice, same for
both designs) except the initial fill. Cross-tile K-partials accumulate in
the south-edge FP32 collectors (§II: round-once-per-column applies to the
on-array chain; the collectors add already-rounded FP32 values).
"""
from __future__ import annotations

import dataclasses
import math

BASELINE = "baseline"
SKEWED = "skewed"
PIPELINES = (BASELINE, SKEWED)

# Per-PE reduction latency in cycles (the paper's central quantity).
CYCLES_PER_ROW = {BASELINE: 2, SKEWED: 1}
# Extra trailing stages at the column end: skewed needs one extra add stage
# (§III.B); both need the rounding stage.
EXTRA_STAGES = {BASELINE: 1, SKEWED: 2}


@dataclasses.dataclass(frozen=True)
class SAConfig:
    """A systolic array instance (the paper evaluates 128×128 @ 1 GHz)."""

    rows: int = 128
    cols: int = 128
    freq_ghz: float = 1.0
    pipeline: str = SKEWED

    def __post_init__(self):
        if self.pipeline not in PIPELINES:
            raise ValueError(f"pipeline must be one of {PIPELINES}")


def tile_latency(M: int, r_used: int, c_used: int, pipeline: str) -> int:
    """Cycles for one resident weight tile to process M streaming rows."""
    fill = CYCLES_PER_ROW[pipeline] * r_used
    return fill + (c_used - 1) + M + EXTRA_STAGES[pipeline]


def gemm_latency(M: int, K: int, N: int, sa: SAConfig) -> int:
    """Total cycles for an (M×K)·(K×N) GEMM on the array.

    K maps to rows (reduction down the column), N to columns; tiles are
    processed back-to-back with double-buffered weight loads. The initial
    weight load of the first tile (r_used cycles, one row per cycle through
    the north ports) is exposed.
    """
    if min(M, K, N) <= 0:
        return 0
    kt, nt = math.ceil(K / sa.rows), math.ceil(N / sa.cols)
    total = min(K, sa.rows)  # exposed initial weight load
    for ki in range(kt):
        r_used = min(sa.rows, K - ki * sa.rows)
        for ni in range(nt):
            c_used = min(sa.cols, N - ni * sa.cols)
            total += tile_latency(M, r_used, c_used, sa.pipeline)
    return total


def gemm_macs(M: int, K: int, N: int) -> int:
    return M * K * N


def utilization(M: int, K: int, N: int, sa: SAConfig) -> float:
    """Fraction of PE-cycles doing useful MACs (PE array occupancy)."""
    cyc = gemm_latency(M, K, N, sa)
    return gemm_macs(M, K, N) / (cyc * sa.rows * sa.cols) if cyc else 0.0


def latency_s(M: int, K: int, N: int, sa: SAConfig) -> float:
    return gemm_latency(M, K, N, sa) / (sa.freq_ghz * 1e9)


def speedup(M: int, K: int, N: int, rows: int = 128, cols: int = 128) -> float:
    """Latency(baseline) / latency(skewed) for one GEMM — the paper's gain."""
    b = gemm_latency(M, K, N, SAConfig(rows, cols, pipeline=BASELINE))
    s = gemm_latency(M, K, N, SAConfig(rows, cols, pipeline=SKEWED))
    return b / s if s else 1.0
