"""The paper's evaluation workloads: MobileNet [18] and ResNet50 [19].

Each conv layer is lowered to the GEMM the WS systolic array executes
(SCALE-Sim-style im2col, the methodology of the paper's reference [8]):

    M = out_h · out_w          (streaming input rows, west edge)
    K = k_h · k_w · C_in       (reduction, mapped onto SA rows)
    N = C_out                  (SA columns)

Depthwise convolutions do not form a dense GEMM; the model supports three
mappings (`dw_mode`):

  * ``packed``  (default) — block-diagonal weight packing: groups of
    ``g = floor(rows / k_h·k_w)`` channels occupy disjoint 9-row bands of the
    array, each SA row streaming its own channel's im2col column (WS rows
    have independent west input ports, so this is legal). One pass handles
    g channels ⇒ GEMM (M, 9·g, g) per pass.
  * ``per_channel`` — C independent (M, 9, 1) GEMMs (naive lowering).
  * ``offload`` — depthwise runs on a vector unit, not the SA (how e.g.
    TPUs treat depthwise); contributes zero SA cycles.

The paper does not pin down its depthwise mapping; EXPERIMENTS.md reports the
headline numbers under ``packed`` and the sensitivity under the other two.
"""
from __future__ import annotations

import dataclasses
import math

from .systolic import SAConfig, gemm_latency, gemm_macs


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    out_hw: int        # output spatial size (square)
    k: int             # kernel size (square)
    c_in: int
    c_out: int
    depthwise: bool = False

    def gemms(self, sa_rows: int, dw_mode: str = "packed"):
        """Yield (M, K, N, repeats) GEMMs this layer lowers to."""
        M = self.out_hw * self.out_hw
        if not self.depthwise:
            yield M, self.k * self.k * self.c_in, self.c_out, 1
            return
        kk = self.k * self.k
        if dw_mode == "offload":
            return
        if dw_mode == "per_channel":
            yield M, kk, 1, self.c_in
            return
        g = max(1, sa_rows // kk)            # channels per block-diagonal pass
        passes = math.ceil(self.c_in / kk if False else self.c_in / g)
        full, rem = divmod(self.c_in, g)
        if full:
            yield M, kk * g, g, full
        if rem:
            yield M, kk * rem, rem, 1
        del passes


@dataclasses.dataclass(frozen=True)
class FCLayer:
    name: str
    c_in: int
    c_out: int

    def gemms(self, sa_rows: int, dw_mode: str = "packed"):
        yield 1, self.c_in, self.c_out, 1


def _dw_sep(idx, hw, c_in, c_out):
    return [
        ConvLayer(f"dw{idx}", hw, 3, c_in, c_in, depthwise=True),
        ConvLayer(f"pw{idx}", hw, 1, c_in, c_out),
    ]


def mobilenet_v1():
    """MobileNetV1 (224×224), Howard et al. 2017 — the paper's [18]."""
    layers = [ConvLayer("conv1", 112, 3, 3, 32)]
    cfg = [  # (hw_out, c_in, c_out)
        (112, 32, 64), (56, 64, 128), (56, 128, 128), (28, 128, 256),
        (28, 256, 256), (14, 256, 512),
        (14, 512, 512), (14, 512, 512), (14, 512, 512), (14, 512, 512),
        (14, 512, 512),
        (7, 512, 1024), (7, 1024, 1024),
    ]
    for i, (hw, ci, co) in enumerate(cfg, start=1):
        layers += _dw_sep(i, hw, ci, co)
    layers.append(FCLayer("fc", 1024, 1000))
    return layers


def _bottleneck(tag, hw, c_in, c_mid, c_out, downsample):
    ls = [
        ConvLayer(f"{tag}.a", hw, 1, c_in, c_mid),
        ConvLayer(f"{tag}.b", hw, 3, c_mid, c_mid),
        ConvLayer(f"{tag}.c", hw, 1, c_mid, c_out),
    ]
    if downsample:
        ls.append(ConvLayer(f"{tag}.ds", hw, 1, c_in, c_out))
    return ls


def resnet50():
    """ResNet50 (224×224), He et al. 2016 — the paper's [19]."""
    layers = [ConvLayer("conv1", 112, 7, 3, 64)]
    spec = [  # (blocks, hw, c_mid, c_out)
        (3, 56, 64, 256), (4, 28, 128, 512), (6, 14, 256, 1024), (3, 7, 512, 2048),
    ]
    c_in = 64
    for si, (blocks, hw, c_mid, c_out) in enumerate(spec, start=1):
        for b in range(blocks):
            layers += _bottleneck(f"s{si}b{b}", hw, c_in, c_mid, c_out,
                                  downsample=(b == 0))
            c_in = c_out
    layers.append(FCLayer("fc", 2048, 1000))
    return layers


WORKLOADS = {"mobilenet": mobilenet_v1, "resnet50": resnet50}


def layer_latency(layer, sa: SAConfig, dw_mode: str = "packed") -> int:
    return sum(gemm_latency(M, K, N, sa) * rep
               for M, K, N, rep in layer.gemms(sa.rows, dw_mode))


def layer_macs(layer, sa_rows: int = 128, dw_mode: str = "packed") -> int:
    """True MAC count (block-diagonal zero tiles don't toggle the datapath,
    so depthwise MACs are counted from the per-channel lowering)."""
    mode = "per_channel" if getattr(layer, "depthwise", False) else dw_mode
    if dw_mode == "offload" and getattr(layer, "depthwise", False):
        mode = "offload"
    return sum(gemm_macs(M, K, N) * rep
               for M, K, N, rep in layer.gemms(sa_rows, mode))


def network_latency(name: str, sa: SAConfig, dw_mode: str = "packed") -> int:
    return sum(layer_latency(l, sa, dw_mode) for l in WORKLOADS[name]())
