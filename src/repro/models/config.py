"""Architecture configuration schema (one instance per assigned arch)."""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 => d_model // num_heads

    # attention
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_pattern: tuple[str, ...] = ("global",)   # cycled over layers
    window: int = 4096              # size of "local" sliding windows
    attn_softcap: float = 0.0       # 0 => off (gemma2: 50)
    final_softcap: float = 0.0      # logits softcap (gemma2: 30)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1              # MoE FFN every N layers (llama4: 2)
    shared_expert: bool = False
    d_ff_dense: int = 0             # FFN width of non-MoE layers (0 => d_ff)
    # force the exact dropless dispatch on *every* path (training included);
    # the serving path is dropless regardless via optflags.moe_dropless_serve.
    # Used by parity references: capacity-drop is not decode-exact.
    moe_dropless: bool = False

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0              # 0 => derived from d_inner / ssm_head_dim
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256

    # structure
    hybrid: bool = False            # hymba: parallel attn ∥ SSM heads per layer
    encoder_layers: int = 0         # >0 => encoder-decoder (whisper)
    frontend_tokens: int = 0        # stub modality frontend sequence length
    frontend_dim: int = 0           # stub frontend embedding dim (0 => d_model)

    # misc
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"               # silu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_scale: bool = False       # gemma: scale embeddings by sqrt(d)

    # distribution policy
    fsdp: bool = False              # shard params over the data(+pod) axes too
    remat: bool = True
    # pad KV heads for TP in the *training* path too (serving paths always
    # pad — cache layout wins everywhere). Empirically per-arch: wins only
    # where the baseline partitioner replicates attention (H ∤ TP with wide
    # heads: phi3, qwen); costs reshards where heads already shard cleanly
    # (gemma, pixtral). See EXPERIMENTS.md §Perf hillclimb 1.
    pad_attn_train: bool = False

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown family {self.family!r}")

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 2048 = 128 (MXU lane) × 16 (TP):
        embedding/lm-head shards stay MXU-aligned on the production mesh.
        Logits beyond vocab_size are masked to -inf in the head."""
        if self.vocab_size % 2048 == 0 or self.vocab_size < 2048:
            return self.vocab_size
        return math.ceil(self.vocab_size / 2048) * 2048

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k cell (DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return (all(p == "local" for p in self.attn_pattern)
                or "local" in self.attn_pattern)

    def layer_kind(self, i: int) -> dict:
        """Structural descriptor of layer i (drives block assembly)."""
        attn = self.attn_pattern[i % len(self.attn_pattern)]
        is_moe = (self.num_experts > 0) and (i % self.moe_every == self.moe_every - 1)
        return {"attn": attn, "moe": is_moe}

    @property
    def stack_period(self) -> int:
        """Length of the repeating structural pattern (scan superblock)."""
        return int(math.lcm(len(self.attn_pattern),
                            self.moe_every if self.num_experts else 1))

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts + shared)."""
        if not self.num_experts:
            return self.param_count()
        total = self.param_count()
        per_moe_layer = self.num_experts * 3 * self.d_model * self.d_ff
        active_moe = self.experts_per_token * 3 * self.d_model * self.d_ff
        n_moe = sum(1 for i in range(self.num_layers)
                    if self.layer_kind(i)["moe"])
        return total - n_moe * (per_moe_layer - active_moe)

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        d, hd = self.d_model, self.hd
        per_layer = 0
        attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                + self.num_heads * hd * d)
        ffn_mats = 2 if self.family == "audio" else 3   # MLP vs SwiGLU
        ffn_dense = ffn_mats * d * (self.d_ff_dense or self.d_ff)
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            p = 0 if self.attn_free else attn
            if self.family == "ssm" or self.hybrid:
                din = self.d_inner
                p += d * (2 * din + 2 * self.ssm_state) + din * d
            if kind["moe"]:
                p += self.num_experts * 3 * d * self.d_ff
                if self.shared_expert:
                    p += 3 * d * self.d_ff
                p += d * self.num_experts
            elif self.family != "ssm":
                p += ffn_dense
            per_layer += p + 2 * d
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        enc = self.encoder_layers * (attn + ffn_dense + 2 * d)
        cross = self.num_layers * (attn if self.is_encdec else 0)
        return per_layer + emb + enc + cross


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str     # train | prefill | decode

SHAPES = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}
