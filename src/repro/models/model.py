"""Model assembly: decoder-only LM, hybrid (attn∥SSM), MoE, and enc-dec.

Layers are grouped into repeating *superblocks* (`cfg.stack_period` layers —
e.g. gemma3's 5 local + 1 global, llama4's dense+MoE pair) and scanned with
stacked parameters: HLO size is O(superblock), independent of depth — the
production pattern that keeps 48-layer × 512-device compiles fast.

Parameters are plain nested dicts (fp32 masters; forward casts via the SA
precision policy). `abstract_params` builds ShapeDtypeStructs via
`jax.eval_shape` so the dry-run never allocates.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.precision import sa_dot
from repro.parallel import sharding as S_
from .config import ArchConfig
from . import layers as L
from .layers import KVCache
from .moe import moe_ffn
from .ssm import mamba2_block


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _dense(rng, fan_in, shape, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * (fan_in ** -0.5)


def _init_attn(rng, cfg: ArchConfig):
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    ks = jax.random.split(rng, 4)
    p = {
        "wq": _dense(ks[0], d, (d, H * hd)),
        "wk": _dense(ks[1], d, (d, KVH * hd)),
        "wv": _dense(ks[2], d, (d, KVH * hd)),
        "wo": _dense(ks[3], H * hd, (H * hd, d)),
    }
    if cfg.qkv_bias:
        p |= {"bq": jnp.zeros((H * hd,)), "bk": jnp.zeros((KVH * hd,)),
              "bv": jnp.zeros((KVH * hd,))}
    return p


def _init_ffn(rng, cfg: ArchConfig, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    if cfg.family == "audio":   # classic 2-layer MLP (whisper)
        return {"w1": _dense(ks[0], d, (d, d_ff)),
                "w2": _dense(ks[1], d_ff, (d_ff, d))}
    return {"wg": _dense(ks[0], d, (d, d_ff)),
            "wu": _dense(ks[1], d, (d, d_ff)),
            "wd": _dense(ks[2], d_ff, (d_ff, d))}


def _init_moe(rng, cfg: ArchConfig):
    d, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": _dense(ks[0], d, (d, E)),
        "wg": _dense(ks[1], d, (E, d, F)),
        "wu": _dense(ks[2], d, (E, d, F)),
        "wd": _dense(ks[3], F, (E, F, d)),
    }
    if cfg.shared_expert:
        sk = jax.random.split(ks[4], 3)
        p |= {"shared_wg": _dense(sk[0], d, (d, F)),
              "shared_wu": _dense(sk[1], d, (d, F)),
              "shared_wd": _dense(sk[2], F, (F, d))}
    return p


def _init_ssm(rng, cfg: ArchConfig):
    d, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(rng, 3)
    conv_dim = 2 * din + 2 * N  # x, B, C get conv'd; (z, dt skip it) — we
    # conv the [x|B|C] concat (width din + 2N) per mamba2
    conv_dim = din + 2 * N
    return {
        "in_proj": _dense(ks[0], d, (d, 2 * din + 2 * N + H)),
        "conv_w": jax.random.normal(ks[1], (4, conv_dim)) * 0.1,
        "dt_bias": jnp.zeros((H,)),
        "A_log": jnp.zeros((H,)),
        "D_skip": jnp.ones((din,)),
        "norm_w": jnp.ones((din,)),
        "out_proj": _dense(ks[2], din, (din, d)),
    }


def _norm_p(cfg):
    p = {"w": jnp.ones((cfg.d_model,))}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((cfg.d_model,))
    return p


def init_layer(rng, cfg: ArchConfig, meta: dict, cross: bool = False):
    ks = jax.random.split(rng, 6)
    p: dict[str, Any] = {"norm1": _norm_p(cfg), "norm2": _norm_p(cfg)}
    if cfg.family == "ssm":
        p["ssm"] = _init_ssm(ks[0], cfg)
        p.pop("norm2")
        return p
    if cfg.hybrid:
        p["attn"] = _init_attn(ks[0], cfg)
        p["ssm"] = _init_ssm(ks[1], cfg)
        p["attn_norm"] = {"w": jnp.ones((cfg.d_model,))}
        p["ssm_norm"] = {"w": jnp.ones((cfg.d_model,))}
    else:
        p["attn"] = _init_attn(ks[0], cfg)
    if cross:
        p["cross"] = _init_attn(ks[2], cfg)
        p["norm_cross"] = _norm_p(cfg)
    if meta["moe"]:
        p["moe"] = _init_moe(ks[3], cfg)
    else:
        p["ffn"] = _init_ffn(ks[4], cfg, cfg.d_ff_dense or cfg.d_ff)
    return p


def init_params(rng, cfg: ArchConfig, dtype=jnp.float32):
    """Full parameter tree; repeated superblocks stacked on axis 0."""
    period = cfg.stack_period
    n_super = cfg.num_layers // period
    assert n_super * period == cfg.num_layers, (cfg.num_layers, period)
    k_emb, k_out, k_layers, k_enc = jax.random.split(rng, 4)

    def one_superblock(k):
        ks = jax.random.split(k, period)
        return tuple(init_layer(ks[j], cfg, cfg.layer_kind(j),
                                cross=cfg.is_encdec) for j in range(period))

    blocks = jax.vmap(one_superblock)(jax.random.split(k_layers, n_super))
    params: dict[str, Any] = {
        "embed": jax.random.normal(
            k_emb, (cfg.padded_vocab, cfg.d_model)) * 0.02,
        "final_norm": _norm_p(cfg),
        "layers": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _dense(k_out, cfg.d_model,
                                   (cfg.d_model, cfg.padded_vocab))
    if cfg.is_encdec:
        def enc_block(k):
            return init_layer(k, cfg, {"attn": "global", "moe": False})
        params["encoder"] = {
            "layers": jax.vmap(enc_block)(
                jax.random.split(k_enc, cfg.encoder_layers)),
            "final_norm": _norm_p(cfg),
        }
    return jax.tree.map(lambda x: x.astype(dtype), params)


def abstract_params(cfg: ArchConfig, dtype=jnp.float32):
    return jax.eval_shape(
        functools.partial(init_params, cfg=cfg, dtype=dtype),
        jax.random.key(0))


# ---------------------------------------------------------------------------
# Caches (decode)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               dtype=jnp.bfloat16, abstract: bool = False,
               kv_pad_to: int = 1,
               paged: tuple[int, int] | None = None):
    """Stacked per-layer cache. Local layers get ring buffers of `window`.

    Every leaf carries the batch dimension at axis 1 (after the stacked
    superblock axis) — including the per-slot KV `positions` — so the serve
    engine can splice one request's cache fragment into batch row `slot` of
    every leaf with a single dynamic-update-slice (continuous batching).

    `kv_pad_to`: TP axis size — KV heads padded up so the cache shards over
    the model axis without per-step resharding (optflags: pad_kv_heads).

    `paged`: `(n_pages, page_size)` — global-attention layers become a
    shared `PagedKVCache` page pool plus a per-slot block table instead of
    per-slot rings (DESIGN.md §5). `seq_len` then caps a single request
    (`max_pages = ceil(seq_len / page_size)` block-table columns) while
    total capacity is the pool's `n_pages · page_size` tokens, shared
    across slots. Local-window layers keep their dense rings (already
    bounded by `window`, they never strand capacity) and SSM/conv state
    stays per-slot, so the engine's fragment splice handles mixed leaves."""
    from repro.models.layers import PagedKVCache, padded_kvh
    period = cfg.stack_period
    n_super = cfg.num_layers // period
    kvh = padded_kvh(cfg.num_kv_heads, kv_pad_to)

    def mk(shape, dt=dtype, fill=0):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.full(shape, fill, dt)

    def layer_cache(j):
        meta = cfg.layer_kind(j)
        c = {}
        if cfg.family != "ssm":
            S = min(cfg.window, seq_len) if meta["attn"] == "local" else seq_len
            if paged is not None and not (meta["attn"] == "local"
                                          and cfg.window
                                          and cfg.window < seq_len):
                n_pages, psz = paged
                max_pages = -(-seq_len // psz)
                c["kv"] = PagedKVCache(
                    k=mk((n_super, n_pages, psz, kvh, cfg.hd)),
                    v=mk((n_super, n_pages, psz, kvh, cfg.hd)),
                    positions=mk((n_super, n_pages, psz), jnp.int32, -1),
                    block_table=mk((n_super, batch, max_pages),
                                   jnp.int32, -1))
            else:
                c["kv"] = KVCache(
                    k=mk((n_super, batch, S, kvh, cfg.hd)),
                    v=mk((n_super, batch, S, kvh, cfg.hd)),
                    positions=mk((n_super, batch, S), jnp.int32, -1))
        if cfg.family == "ssm" or cfg.hybrid:
            c["ssm"] = (
                mk((n_super, batch, cfg.n_ssm_heads, cfg.ssm_head_dim,
                    cfg.ssm_state), jnp.float32),
                mk((n_super, batch, 3, cfg.d_inner + 2 * cfg.ssm_state)))
        if cfg.is_encdec:
            c["cross"] = KVCache(
                k=mk((n_super, batch, cfg.frontend_tokens, cfg.num_kv_heads,
                      cfg.hd)),
                v=mk((n_super, batch, cfg.frontend_tokens, cfg.num_kv_heads,
                      cfg.hd)),
                positions=mk((n_super, batch, cfg.frontend_tokens),
                             jnp.int32, -1))
        return c

    return tuple(layer_cache(j) for j in range(period))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _sublayer(x, p, cfg, meta, positions, cache, pos, encoder_out,
              prefix_len: int = 0, decode_multi: bool = False):
    """One transformer layer. Returns (x, new_cache)."""
    new_cache: dict[str, Any] = {}
    h = L.norm_apply(x, p["norm1"], cfg.norm, cfg.norm_eps)
    if cfg.family == "ssm":
        ssm_cache = cache.get("ssm") if cache else None
        mix, st = mamba2_block(h, p["ssm"], cfg,
                               state=ssm_cache[0] if ssm_cache else None,
                               conv_cache=ssm_cache[1] if ssm_cache else None)
        if cache is not None:
            new_cache["ssm"] = st
        return x + mix.astype(x.dtype), (new_cache if cache is not None else None)
    if cfg.hybrid:
        a, kv = L.attention_block(h, p["attn"], cfg, meta, positions,
                                  cache=cache.get("kv") if cache else None,
                                  pos=pos, prefix_len=prefix_len,
                                  decode_multi=decode_multi)
        ssm_cache = cache.get("ssm") if cache else None
        s, st = mamba2_block(h, p["ssm"], cfg,
                             state=ssm_cache[0] if ssm_cache else None,
                             conv_cache=ssm_cache[1] if ssm_cache else None)
        mix = 0.5 * (L.rmsnorm(a, p["attn_norm"]["w"], cfg.norm_eps)
                     + L.rmsnorm(s, p["ssm_norm"]["w"], cfg.norm_eps))
        if cache is not None:
            new_cache |= {"kv": kv, "ssm": st}
    else:
        mix, kv = L.attention_block(h, p["attn"], cfg, meta, positions,
                                    cache=cache.get("kv") if cache else None,
                                    pos=pos, prefix_len=prefix_len,
                                    decode_multi=decode_multi)
        if cache is not None:
            new_cache["kv"] = kv
    x = x + mix.astype(x.dtype)
    if cfg.is_encdec and encoder_out is not None:
        h = L.norm_apply(x, p["norm_cross"], cfg.norm, cfg.norm_eps)
        ca, cross_kv = L.attention_block(
            h, p["cross"], cfg, {"attn": "global"}, positions,
            cache=None, rope=False, causal=False,
            kv_override=_encoder_kv(p["cross"], cfg, encoder_out))
        x = x + ca.astype(x.dtype)
        if cache is not None:
            new_cache["cross"] = cache.get("cross")
    h = L.norm_apply(x, p["norm2"], cfg.norm, cfg.norm_eps)
    aux = None
    if meta["moe"]:
        from repro.core import optflags
        # serving (cache threaded) routes MoE through the dropless dispatch:
        # capacity-drop is a training-time approximation that breaks
        # prefill+decode ≡ full-forward exactness (and drops user tokens)
        dropless = cfg.moe_dropless or (
            cache is not None and optflags.enabled("moe_dropless_serve"))
        f, aux = moe_ffn(h, p["moe"], cfg, cfg.act, dropless=dropless)
    elif cfg.family == "audio":
        f = L.ffn_mlp(h, p["ffn"], "gelu")
    else:
        f = L.ffn_swiglu(h, p["ffn"], cfg.act)
    return x + f.astype(x.dtype), (new_cache if cache is not None else aux)


def _encoder_kv(p, cfg, encoder_out):
    B, S, _ = encoder_out.shape
    k = sa_dot(encoder_out.reshape(B * S, -1),
               p["wk"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    v = sa_dot(encoder_out.reshape(B * S, -1),
               p["wv"]).reshape(B, S, cfg.num_kv_heads, cfg.hd)
    return k, v


def _sinusoid(T, d):
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
    ang = pos / (10000.0 ** (dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[None]


def encode(params, cfg: ArchConfig, frontend_embeds):
    """Encoder stack over stub frontend embeddings (B, S, d_model)."""
    x = frontend_embeds + _sinusoid(frontend_embeds.shape[1],
                                    cfg.d_model).astype(frontend_embeds.dtype)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]),
                                 (x.shape[0], x.shape[1]))

    def body(h, p):
        h2 = L.norm_apply(h, p["norm1"], cfg.norm, cfg.norm_eps)
        a, _ = L.attention_block(h2, p["attn"], cfg, {"attn": "global"},
                                 positions, rope=False, causal=False)
        h = h + a.astype(h.dtype)
        h2 = L.norm_apply(h, p["norm2"], cfg.norm, cfg.norm_eps)
        h = h + L.ffn_mlp(h2, p["ffn"], "gelu").astype(h.dtype)
        return h, None

    x, _ = lax.scan(body, x, params["encoder"]["layers"])
    return L.norm_apply(x, params["encoder"]["final_norm"], cfg.norm,
                        cfg.norm_eps)


def forward(params, cfg: ArchConfig, tokens, *, positions=None, cache=None,
            pos=None, frontend_embeds=None, last_only: bool = False,
            last_index=None, prefix_len: int = 0, decode_multi: bool = False):
    """Token ids (B, T) → logits. Returns (logits, new_cache, aux).

    `cache`/`pos` engage the decode path; `pos` is a (B,) int32 vector of
    per-sequence positions (each batch row — serving *slot* — may be at its
    own depth; a scalar is broadcast for single-sequence callers).
    `frontend_embeds` feeds the modality stub (vlm: prepended to the text
    sequence; audio: encoder input for cross-attention). `prefix_len`
    (static) is the continued-prefill offset: `tokens` holds only a
    prompt's uncached suffix and the dense cache's first `prefix_len` rows
    hold pre-loaded KV (serve prefix-cache hits; see layers.attention_block).
    `decode_multi` (static) marks the T tokens as T consecutive *decode*
    steps per slot (speculative verify, DESIGN.md §9) instead of a prefill
    fragment — row t writes and attends at position pos+t. `last_index`
    (traced, used with `last_only`) selects WHICH row feeds the lm_head
    instead of the static -1: bucketed prefill (serve prompt-length
    bucketing) right-pads the token block, so the real prompt's logits
    live at row `last_index`, not the padded block's end.
    """
    B, T = tokens.shape
    if decode_multi and (cfg.family == "ssm" or cfg.hybrid):
        # a rejected draft would leave the recurrent state advanced past
        # the rollback point; attention caches roll back by position,
        # ssm states cannot — the serve engine gates spec decoding off
        # for these families (ServeEngine.spec_decoding_on)
        raise ValueError("decode_multi needs rollback-by-position; "
                         "ssm/hybrid recurrent state cannot roll back")
    compute_dtype = jnp.bfloat16
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    x = S_.constrain(x, "batch", None, None)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    encoder_out = None
    if cfg.family == "vlm" and frontend_embeds is not None:
        x = jnp.concatenate([frontend_embeds.astype(compute_dtype), x], axis=1)
        T = x.shape[1]
    elif cfg.is_encdec and frontend_embeds is not None:
        encoder_out = encode(params, cfg, frontend_embeds.astype(compute_dtype))
    if pos is None and prefix_len:
        # continued prefill: positions (and a 1-token suffix's decode-path
        # write) start at the first uncached token
        pos = prefix_len
    if pos is not None:
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    if positions is None:
        if pos is not None:
            positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        else:
            positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    period = cfg.stack_period

    def superblock(x, xs):
        p_sb, cache_sb = xs
        x = S_.constrain(x, "batch", None, None)  # pin the residual stream
        from repro.core import optflags
        if optflags.enabled("bf16_params_in_layers"):
            # cast matrices to bf16 *before* use so FSDP all-gathers move
            # bf16 payloads (2× ICI saving; numerically identical — sa_dot
            # quantizes to bf16 at consumption anyway). 1-D leaves (norms,
            # dt_bias, A_log) stay fp32.
            p_sb = jax.tree.map(
                lambda w: w.astype(jnp.bfloat16)
                if (hasattr(w, "ndim") and w.ndim >= 2
                    and w.dtype == jnp.float32) else w, p_sb)
        new_caches = []
        aux_acc = jnp.zeros((2,), jnp.float32)
        for j in range(period):
            c_j = None if cache_sb is None else cache_sb[j]
            x, extra = _sublayer(x, p_sb[j], cfg, cfg.layer_kind(j),
                                 positions, c_j, pos, encoder_out,
                                 prefix_len, decode_multi)
            if cache_sb is not None:
                new_caches.append(extra)
            elif isinstance(extra, dict):   # moe aux losses
                aux_acc = aux_acc + jnp.stack(
                    [extra["load_balance"], extra["router_z"]])
        return x, (tuple(new_caches) if cache_sb is not None else None,
                   aux_acc)

    if cfg.remat and cache is None:   # remat for training only
        superblock = jax.checkpoint(
            superblock, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    cache_xs = cache if cache is not None else None
    x, (new_cache, aux_sb) = lax.scan(
        superblock, x, (params["layers"], cache_xs))
    aux = {"load_balance": jnp.sum(aux_sb[:, 0]),
           "router_z": jnp.sum(aux_sb[:, 1])}
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if last_only:
        if last_index is not None:
            x = lax.dynamic_slice_in_dim(
                x, jnp.asarray(last_index, jnp.int32), 1, axis=1)
        else:
            x = x[:, -1:]
    head = params.get("lm_head", None)
    if head is None:
        head = params["embed"].T
    logits = sa_dot(x.reshape(-1, cfg.d_model),
                    head).reshape(x.shape[0], x.shape[1], cfg.padded_vocab)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if cfg.padded_vocab != cfg.vocab_size:   # mask padding logits (no reshard)
        valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(valid, logits, -jnp.inf)
    return logits, new_cache, aux


def lm_loss(params, cfg: ArchConfig, tokens, labels, *, frontend_embeds=None,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (fp32 logsumexp) + MoE aux losses."""
    logits, _, aux = forward(params, cfg, tokens,
                             frontend_embeds=frontend_embeds)
    if cfg.family == "vlm" and frontend_embeds is not None:
        logits = logits[:, frontend_embeds.shape[1]:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    return nll + aux_weight * (aux["load_balance"] + aux["router_z"]), nll
