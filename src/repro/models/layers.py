"""Model layers. Every GEMM routes through the SA precision policy
(`repro.core.precision.sa_dot` / `sa_einsum`) — the paper's reduced-precision
chained-accumulate contract is the framework's arithmetic everywhere.

Attention is flash-style blockwise (two-level `lax.scan`, online softmax in
fp32): O(T·block) memory, compiles at 32k/500k sequence lengths, and maps the
"never materialize the unnormalized chain" idea to the softmax accumulator.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.precision import sa_dot, sa_einsum

# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = ((x32 - mu) * lax.rsqrt(var + eps) * w.astype(jnp.float32)
         + b.astype(jnp.float32))
    return y.astype(x.dtype)


def norm_apply(x, p, kind="rmsnorm", eps=1e-6):
    if kind == "layernorm":
        return layernorm(x, p["w"], p["b"], eps)
    return rmsnorm(x, p["w"], eps)


def act_fn(x, kind="silu"):
    # one activation table for fused and unfused paths: drift between the
    # two would break the fused_epilogue flag's numerics-preserving A/B
    from repro.kernels.sa_matmul import EPILOGUES, apply_act
    if kind not in EPILOGUES or kind == "none":
        raise ValueError(f"unknown activation {kind!r}")
    return apply_act(x, kind)


def _fuse_epilogue() -> bool:
    from repro.core import optflags
    return optflags.enabled("fused_epilogue")


def softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)


def apply_rope(x, positions, theta: float):
    """x: (..., T, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Blockwise attention (training / prefill)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, kv_pos, causal: bool, window: int):
    """(bq, bkv) additive bias: 0 where visible, -inf where masked."""
    ok = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        ok &= q_pos[:, None] - kv_pos[None, :] < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def _div_block(n, target):  # largest divisor of n that is <= target
    b = min(target, n)
    while n % b:
        b -= 1
    return b


def _scores(q_i, k_j, q_pos, kv_pos, causal, window, cap, scale):
    """Raw + masked-capped scores for one (q-block, kv-block) tile."""
    s_raw = sa_einsum("bqhgd,bkhd->bhgqk", q_i, k_j).astype(jnp.float32)
    s = softcap(s_raw * scale, cap)
    s = s + _mask_bias(q_pos, kv_pos, causal, window)[None, None, None]
    return s_raw, s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, cap, q_offset, bq, bkv, scale):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, cap, q_offset, bq, bkv,
                             scale)
    return out


def _flash_fwd_impl(q, k, v, causal, window, cap, q_offset, bq, bkv, scale):
    """Online-softmax forward. Returns (out (B,KVH,g,T,hd), lse)."""
    B, T, KVH, g, hd = q.shape
    S = k.shape[1]
    nq, nkv = T // bq, S // bkv
    qb = q.reshape(B, nq, bq, KVH, g, hd)
    kb, vb = (x.reshape(B, nkv, bkv, KVH, hd) for x in (k, v))

    def q_step(_, qi):
        q_i, iq = qi
        q_pos = q_offset + iq * bq + jnp.arange(bq)

        def kv_step(carry, kvj):
            acc, m, l = carry
            k_j, v_j, jk = kvj
            kv_pos = jk * bkv + jnp.arange(bkv)
            _, s = _scores(q_i, k_j, q_pos, kv_pos, causal, window, cap, scale)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # fully-masked tiles (sliding windows) leave m_new = -inf; the
            # guard keeps exp() at exactly 0 instead of NaN
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            # online softmax: the running (unnormalized) accumulator is
            # normalized once at the end — the softmax analogue of the
            # round-once-per-column reduction.
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.exp(m - m_safe)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = sa_einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype), v_j)
            acc = acc * corr[..., None] + pv.astype(jnp.float32)
            return (acc, m_new, l), None

        acc0 = jnp.zeros((B, KVH, g, bq, hd), jnp.float32)
        m0 = jnp.full((B, KVH, g, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KVH, g, bq), jnp.float32)
        (acc, m, l), _ = lax.scan(
            kv_step, (acc0, m0, l0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return None, (out.astype(q.dtype), lse)

    _, (blocks, lses) = lax.scan(q_step, None,
                                 (qb.swapaxes(0, 1), jnp.arange(nq)))
    # blocks: (nq, B, KVH, g, bq, hd) → (B, KVH, g, T, hd)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(B, KVH, g, T, hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KVH, g, T)
    return out, lse


def _flash_fwd(q, k, v, causal, window, cap, q_offset, bq, bkv, scale):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, cap, q_offset, bq,
                               bkv, scale)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, cap, q_offset, bq, bkv, scale, res, dout):
    """Flash backward: recompute p per tile; O(block²) memory.

    dq pass scans q blocks (kv inner); dk/dv pass scans kv blocks (q inner).
    """
    q, k, v, out, lse = res
    B, T, KVH, g, hd = q.shape
    S = k.shape[1]
    nq, nkv = T // bq, S // bkv
    qb = q.reshape(B, nq, bq, KVH, g, hd)
    kb, vb = (x.reshape(B, nkv, bkv, KVH, hd) for x in (k, v))
    doutb = dout.reshape(B, KVH, g, nq, bq, hd)
    lseb = lse.reshape(B, KVH, g, nq, bq)
    # delta = rowsum(dout ⊙ out)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)
    deltab = delta.reshape(B, KVH, g, nq, bq)

    def p_and_ds(q_i, k_j, v_j, do_i, lse_i, dl_i, iq, jk):
        q_pos = q_offset + iq * bq + jnp.arange(bq)
        kv_pos = jk * bkv + jnp.arange(bkv)
        s_raw, s = _scores(q_i, k_j, q_pos, kv_pos, causal, window, cap, scale)
        p = jnp.exp(s - lse_i[..., None])                      # (B,h,g,bq,bkv)
        dp = sa_einsum("bhgqd,bkhd->bhgqk", do_i, v_j).astype(jnp.float32)
        ds = p * (dp - dl_i[..., None])
        if cap:   # softcap jacobian: d tanh = 1 - tanh²
            ds = ds * (1.0 - (softcap(s_raw * scale, cap) / cap) ** 2)
        return p, ds * scale

    def dq_step(_, xs):
        q_i, do_i, lse_i, dl_i, iq = xs

        def inner(dq_acc, kvj):
            k_j, v_j, jk = kvj
            _, ds = p_and_ds(q_i, k_j, v_j, do_i, lse_i, dl_i, iq, jk)
            dq_acc += sa_einsum("bhgqk,bkhd->bqhgd", ds.astype(q.dtype), k_j
                                ).astype(jnp.float32)
            return dq_acc, None

        dq0 = jnp.zeros((B, bq, KVH, g, hd), jnp.float32)
        dq_i, _ = lax.scan(inner, dq0, (kb.swapaxes(0, 1),
                                        vb.swapaxes(0, 1), jnp.arange(nkv)))
        return None, dq_i

    _, dq_blocks = lax.scan(
        dq_step, None,
        (qb.swapaxes(0, 1), doutb.transpose(3, 0, 1, 2, 4, 5),
         lseb.transpose(3, 0, 1, 2, 4), deltab.transpose(3, 0, 1, 2, 4),
         jnp.arange(nq)))
    dq = dq_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, T, KVH, g, hd)

    def dkv_step(_, xs):
        k_j, v_j, jk = xs

        def inner(carry, qs):
            dk_acc, dv_acc = carry
            q_i, do_i, lse_i, dl_i, iq = qs
            p, ds = p_and_ds(q_i, k_j, v_j, do_i, lse_i, dl_i, iq, jk)
            dv_acc += sa_einsum("bhgqk,bhgqd->bkhd", p.astype(q.dtype), do_i
                                ).astype(jnp.float32)
            dk_acc += sa_einsum("bhgqk,bqhgd->bkhd", ds.astype(q.dtype), q_i
                                ).astype(jnp.float32)
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, bkv, KVH, hd), jnp.float32)
        (dk_j, dv_j), _ = lax.scan(
            inner, (z, z),
            (qb.swapaxes(0, 1), doutb.transpose(3, 0, 1, 2, 4, 5),
             lseb.transpose(3, 0, 1, 2, 4), deltab.transpose(3, 0, 1, 2, 4),
             jnp.arange(nq)))
        return None, (dk_j, dv_j)

    _, (dk_blocks, dv_blocks) = lax.scan(
        dkv_step, None, (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
                         jnp.arange(nkv)))
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, KVH, hd)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, S, KVH, hd)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                        q_offset=0, block_q=1024, block_kv=1024, scale=None):
    """q: (B, T, H, hd); k, v: (B, S, KVH, hd) → (B, T, H, hd).

    Flash-style attention with a custom VJP: forward keeps only (out, lse);
    backward recomputes probabilities tile-by-tile — O(T·block) memory in
    both passes at any sequence length. GQA via grouped query heads; all
    contractions under the SA contract (bf16 in, fp32 accumulate).
    """
    B, T, H, hd = q.shape
    S, KVH = k.shape[1], k.shape[2]
    g = H // KVH
    scale = scale or hd ** -0.5
    bq, bkv = _div_block(T, block_q), _div_block(S, block_kv)
    qg = q.reshape(B, T, KVH, g, hd)
    out = _flash(qg, k, v, causal, window, cap, q_offset, bq, bkv, scale)
    # (B, KVH, g, T, hd) → (B, T, H, hd)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)


def decode_attention(q, k_cache, v_cache, kv_positions, pos, *, window=0,
                     cap=0.0, scale=None):
    """Single-token attention against a (possibly ring-buffer) cache.

    q: (B, 1, H, hd); caches: (B, S, KVH, hd); kv_positions: (B, S) original
    token position per cache slot (-1 = empty); pos: (B,) per-sequence
    current position — rows of the batch may sit at different depths
    (continuous batching: each slot serves an independent request).
    """
    B, _, H, hd = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    g = H // KVH
    scale = scale or hd ** -0.5
    qg = q.reshape(B, KVH, g, hd)
    s = sa_einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    # softcap with the constants folded on the host: a mul→div→tanh chain
    # is NOT fusion-stable on XLA CPU (eager vs jit codegen round the
    # intermediate differently), while single-mul→tanh is — and the fused
    # paged kernel computes this exact expression, so the bit-parity pin
    # (tests/test_decode_kernel.py) holds in every execution regime
    s = cap * jnp.tanh(s * (scale / cap)) if cap else s * scale
    ok = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window:
        ok &= kv_positions > pos[:, None] - window
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    # safe-row softmax: a slot with zero valid cache entries (freshly freed
    # slot, wholly-unmapped block table) is a row of -inf, which
    # jax.nn.softmax turns into NaNs. Guarding the max keeps exp() at
    # exactly 0 and the floor on the normalizer yields an all-zero row;
    # non-empty rows have l >= 1 (the max element contributes exp(0) = 1),
    # so the maximum() never engages and the result is bit-identical to
    # jax.nn.softmax. The fused paged kernel carries the same guard.
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = sa_einsum("bhgk,bkhd->bhgd", p.astype(q.dtype), v_cache)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention)
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array          # (B, S_cache, KVH, hd)
    v: jax.Array
    positions: jax.Array  # (B, S_cache) int32 per-slot positions, -1 = empty


class PagedKVCache(NamedTuple):
    """Paged KV layout: a global page pool shared by every batch slot.

    Slots own whole pages via `block_table`; a short request maps few pages
    while a long neighbour maps many — capacity is pooled instead of each
    slot owning a full fixed-length ring (DESIGN.md §5). Page 0 is the
    reserved *trash* page: decode writes from slots with no mapped page for
    their current position (free / just-retired slots keep decoding until
    the next scheduler tick) land there, and it is never handed out by the
    allocator, so a stale write can never corrupt a live request.
    """
    k: jax.Array            # (n_pages, page_size, KVH, hd)
    v: jax.Array
    positions: jax.Array    # (n_pages, page_size) int32, -1 = empty
    block_table: jax.Array  # (B, max_pages) int32 page ids, -1 = unmapped


def gather_pages(cache: PagedKVCache):
    """Gather each slot's mapped pages into a virtually-contiguous view.

    Returns (k, v, positions) shaped like a dense per-slot cache of length
    S = max_pages·page_size, so `decode_attention` runs unchanged on top.
    Unmapped block-table entries gather the trash page; their k/v are
    zeroed (a free slot's garbage row can carry NaNs — and 0·NaN = NaN
    would leak through the masked softmax) and positions forced to -1, so
    they are masked out exactly like empty dense-ring entries. An explicit
    page-0 entry is treated as unmapped too: id 0 is the reserved trash
    page the allocator never hands out, and the fused decode kernel masks
    it the same way — the two paths must agree on every block table.
    """
    B, P = cache.block_table.shape
    psz = cache.k.shape[1]
    safe = jnp.maximum(cache.block_table, 0)              # (B, P)
    mapped = (cache.block_table > 0)[:, :, None]          # (B, P, 1)
    kvhd = cache.k.shape[2:]
    k = jnp.where(mapped[..., None, None], cache.k[safe], 0)
    v = jnp.where(mapped[..., None, None], cache.v[safe], 0)
    pos = jnp.where(mapped, cache.positions[safe], -1)
    return (k.reshape(B, P * psz, *kvhd), v.reshape(B, P * psz, *kvhd),
            pos.reshape(B, P * psz))


def qkv_project(x, p, cfg, meta):
    """x: (B, T, D) → q (B,T,H,hd), k/v (B,T,KVH,hd)."""
    B, T, _ = x.shape
    xf = x.reshape(B * T, -1)
    # fused: bias rides the GEMM epilogue — added to the fp32 chain before
    # the single output rounding instead of to the already-rounded output
    fused = cfg.qkv_bias and _fuse_epilogue()
    q = sa_dot(xf, p["wq"], bias=p["bq"] if fused else None
               ).reshape(B, T, cfg.num_heads, cfg.hd)
    k = sa_dot(xf, p["wk"], bias=p["bk"] if fused else None
               ).reshape(B, T, cfg.num_kv_heads, cfg.hd)
    v = sa_dot(xf, p["wv"], bias=p["bv"] if fused else None
               ).reshape(B, T, cfg.num_kv_heads, cfg.hd)
    if cfg.qkv_bias and not fused:
        q = q + p["bq"].reshape(cfg.num_heads, cfg.hd)
        k = k + p["bk"].reshape(cfg.num_kv_heads, cfg.hd)
        v = v + p["bv"].reshape(cfg.num_kv_heads, cfg.hd)
    return q, k, v


def attn_out(x_attn, p):
    B, T, H, hd = x_attn.shape
    return sa_dot(x_attn.reshape(B * T, H * hd), p["wo"]).reshape(B, T, -1)


def padded_kvh(kvh: int, tp: int) -> int:
    """KV head count after TP padding (optflags: pad_kv_heads)."""
    from repro.core import optflags
    if tp <= 1 or kvh % tp == 0 or not optflags.enabled("pad_kv_heads"):
        return kvh
    return -(-kvh // tp) * tp


def _pad_heads(q, k, v, kvh_target: int):
    """Zero-pad KV heads (and the kv-major grouped Q heads) to kvh_target.

    Without this, KVH that doesn't divide the 16-way model axis makes the
    SPMD partitioner REPLICATE every attention einsum across the axis
    (16× FLOPs on phi3; full cache reshards per decode step). Zero k/v heads
    produce garbage outputs only in the padded q-head slots, which are
    sliced away (EXPERIMENTS.md §Perf, hillclimb 1).
    """
    B, T, KVH, hd = k.shape
    H = q.shape[2]
    g = H // KVH
    pad = kvh_target - KVH
    if pad <= 0:
        return q, k, v, H
    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    q = jnp.pad(q, ((0, 0), (0, 0), (0, pad * g), (0, 0)))
    return q, k, v, H


def attention_block(x, p, cfg, meta, positions, cache: KVCache | None = None,
                    pos=None, rope: bool = True, causal: bool = True,
                    kv_override=None, prefix_len: int = 0,
                    decode_multi: bool = False):
    """Full attention sub-layer. Returns (out, new_cache).

    meta: layer descriptor {"attn": "global"|"local"}. If `cache` is given and
    x is a single token, runs the decode path (ring-buffer update for local
    layers). `kv_override` supplies cross-attention K/V source outputs.
    `prefix_len` (static) engages continued prefill: the dense cache's first
    `prefix_len` rows already hold KV for positions [0, prefix_len) — the
    serve engine's prefix-cache hits load them from shared pool pages — and
    x carries only the uncached suffix, whose KV is written at offset
    `prefix_len` and whose queries attend over [prefix ‖ suffix].
    `decode_multi` (static) treats the T tokens of x as T *consecutive
    decode steps* per slot (speculative verify, DESIGN.md §9) rather than a
    prefill fragment: row t writes KV at position pos+t and attends like a
    single-token decode at that position.
    """
    from repro.parallel import sharding as S_
    window = cfg.window if meta.get("attn") == "local" else 0
    theta = cfg.rope_theta
    q, k, v = qkv_project(x, p, cfg, meta)
    rope_kv = kv_override is None
    if kv_override is not None:          # cross-attention: kv from encoder
        k, v = kv_override
    if rope:                             # positions: (B, T)
        q = apply_rope(q.transpose(0, 2, 1, 3), positions[:, None, :],
                       theta).transpose(0, 2, 1, 3)
        if rope_kv:
            k = apply_rope(k.transpose(0, 2, 1, 3), positions[:, None, :],
                           theta).transpose(0, 2, 1, 3)
    # TP head padding: cache layout wins if present; the pure-training path
    # pads only where the arch opts in (cfg.pad_attn_train — see config.py)
    H_orig = q.shape[2]
    if cache is not None:
        kvh_target = cache.k.shape[-2]
    elif cfg.pad_attn_train:
        kvh_target = padded_kvh(k.shape[2], S_.axis_count("model"))
    else:
        kvh_target = k.shape[2]
    padding_active = kvh_target != k.shape[2] or cache is not None
    q, k, v, H_orig = _pad_heads(q, k, v, kvh_target)
    if padding_active:
        # pin the head-sharded layout (cache-matching / replication fix);
        # un-padded training paths keep XLA's own layout choice — forcing
        # head sharding there only adds reshards (measured, §Perf)
        q = S_.constrain(q, "batch", None, "model", None)
        k = S_.constrain(k, "batch", None, "model", None)
        v = S_.constrain(v, "batch", None, "model", None)
    new_cache = None
    if cache is not None and decode_multi:
        # multi-token decode (speculative verify): row t of the T-token
        # block is the decode step for position pos+t — it writes KV at
        # its own position, then the T query rows are folded into the
        # batch axis so every row runs the *single-token* decode
        # arithmetic (same reduction shapes, same kernel dispatch, with
        # kv_positions <= pos+t standing in for the causal mask). XLA's
        # row arithmetic is batch-fold stable, so under greedy decoding
        # row t is bit-identical to the sequential decode step it
        # replaces — that is the whole exactness argument for acceptance.
        B, T = x.shape[0], x.shape[1]
        H, hd = q.shape[2], q.shape[3]
        tpos = positions.astype(jnp.int32)              # (B, T) = pos + t
        qf = q.reshape(B * T, 1, H, hd)
        posf = tpos.reshape(B * T)
        from repro.core import optflags
        from repro.core.precision import current_policy
        from repro.kernels import ops as K
        if isinstance(cache, PagedKVCache):
            psz = cache.k.shape[1]
            P = cache.block_table.shape[1]
            page_i = tpos // psz                        # (B, T)
            off = tpos % psz
            b = jnp.arange(B)[:, None]
            pid = cache.block_table[b, jnp.clip(page_i, 0, P - 1)]
            pid = jnp.where((page_i < P) & (pid >= 0), pid, 0)
            k_c = cache.k.at[pid, off].set(k.astype(cache.k.dtype))
            v_c = cache.v.at[pid, off].set(v.astype(cache.v.dtype))
            pos_c = cache.positions.at[pid, off].set(tpos)
            new_cache = PagedKVCache(k_c, v_c, pos_c, cache.block_table)
            impl = optflags.decode_attn_impl()
            if impl == "fused" and K.fused_decode_supported(current_policy()):
                btf = jnp.repeat(new_cache.block_table, T, axis=0)
                o = K.paged_decode_attention(
                    qf, new_cache.k, new_cache.v, new_cache.positions,
                    btf, posf, window=window, cap=cfg.attn_softcap)
            else:
                k_g, v_g, pos_g = gather_pages(new_cache)
                o = decode_attention(
                    qf, jnp.repeat(k_g, T, axis=0),
                    jnp.repeat(v_g, T, axis=0),
                    jnp.repeat(pos_g, T, axis=0), posf, window=window,
                    cap=cfg.attn_softcap)
        else:
            S = cache.k.shape[1]
            slot = tpos % S                             # (B, T)
            b = jnp.arange(B)[:, None]
            k_c = cache.k.at[b, slot].set(k.astype(cache.k.dtype))
            v_c = cache.v.at[b, slot].set(v.astype(cache.v.dtype))
            pos_c = cache.positions.at[b, slot].set(tpos)
            new_cache = KVCache(k_c, v_c, pos_c)
            o = decode_attention(
                qf, jnp.repeat(k_c, T, axis=0), jnp.repeat(v_c, T, axis=0),
                jnp.repeat(pos_c, T, axis=0), posf, window=window,
                cap=cfg.attn_softcap)
        o = o.reshape(B, T, H, hd)
    elif (cache is not None and x.shape[1] == 1
            and isinstance(cache, PagedKVCache)):
        # paged write: position p of slot b lives at offset p % page_size of
        # page block_table[b, p // page_size]. Rows whose position falls
        # outside their mapped pages (free slots, post-retirement steps
        # inside a chunk) write to the reserved trash page 0 instead —
        # never to a page another request owns.
        B = x.shape[0]
        psz = cache.k.shape[1]
        P = cache.block_table.shape[1]
        page_i = (pos // psz).astype(jnp.int32)         # (B,)
        off = (pos % psz).astype(jnp.int32)
        b = jnp.arange(B)
        pid = cache.block_table[b, jnp.clip(page_i, 0, P - 1)]
        pid = jnp.where((page_i < P) & (pid >= 0), pid, 0)
        k_c = cache.k.at[pid, off].set(k[:, 0].astype(cache.k.dtype))
        v_c = cache.v.at[pid, off].set(v[:, 0].astype(cache.v.dtype))
        pos_c = cache.positions.at[pid, off].set(pos.astype(jnp.int32))
        new_cache = PagedKVCache(k_c, v_c, pos_c, cache.block_table)
        # attention over the slot's mapped pages only; page order in the
        # block table is allocation order == sequence order, so the paged
        # view is position-sorted exactly like a non-wrapped ring. Default
        # impl is the fused Pallas kernel walking the block table in-kernel
        # (no dense gathered view in HBM); REPRO_DECODE_ATTN=gather keeps
        # the materializing path as the bit-identical A/B fallback, and
        # policies the kernel can't reproduce (FP8 in, non-fp32 out) fall
        # back automatically.
        from repro.core import optflags
        from repro.core.precision import current_policy
        from repro.kernels import ops as K
        impl = optflags.decode_attn_impl()
        if impl == "fused" and K.fused_decode_supported(current_policy()):
            o = K.paged_decode_attention(
                q, new_cache.k, new_cache.v, new_cache.positions,
                new_cache.block_table, pos, window=window,
                cap=cfg.attn_softcap)
        else:
            k_g, v_g, pos_g = gather_pages(new_cache)
            o = decode_attention(q, k_g, v_g, pos_g, pos, window=window,
                                 cap=cfg.attn_softcap)
    elif cache is not None and x.shape[1] == 1:
        # per-slot ring write: row b of the batch is an independent request
        # at its own depth, so each row scatters into its own ring slot
        B = x.shape[0]
        S = cache.k.shape[1]
        slot = (pos % S).astype(jnp.int32)              # (B,)
        b = jnp.arange(B)
        k_c = cache.k.at[b, slot].set(k[:, 0].astype(cache.k.dtype))
        v_c = cache.v.at[b, slot].set(v[:, 0].astype(cache.v.dtype))
        pos_c = cache.positions.at[b, slot].set(pos.astype(jnp.int32))
        new_cache = KVCache(k_c, v_c, pos_c)
        o = decode_attention(q, k_c, v_c, pos_c, pos, window=window,
                             cap=cfg.attn_softcap)
    else:
        from repro.core import optflags
        if cache is not None and prefix_len:
            # continued prefill (serve prefix-cache hit): suffix queries
            # attend over [cached prefix ‖ fragment] at their absolute
            # offset. The concat keeps the kv length — and therefore the
            # flash kv tiling and online-softmax accumulation order —
            # identical to a full prefill of the whole prompt, and the
            # cache round-trip is value-preserving (fp32 cache, or a bf16
            # cache of values the SA contract re-quantizes to bf16 anyway),
            # so the suffix rows come out bit-identical to full prefill.
            kp = cache.k[:, :prefix_len].astype(k.dtype)
            vp = cache.v[:, :prefix_len].astype(v.dtype)
            o = blockwise_attention(
                q, jnp.concatenate([kp, k], axis=1),
                jnp.concatenate([vp, v], axis=1), causal=causal,
                window=window, cap=cfg.attn_softcap, q_offset=prefix_len)
        elif cache is not None and optflags.enabled("pallas_attention"):
            # serving prefill is forward-only: use the Pallas flash kernel
            # (VMEM-resident softmax state; kernels/sa_attention.py)
            from repro.kernels.ops import sa_attention
            o = sa_attention(q.transpose(0, 2, 1, 3),
                             k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             causal=causal, window=window,
                             cap=cfg.attn_softcap).transpose(0, 2, 1, 3)
        else:
            o = blockwise_attention(q, k, v, causal=causal, window=window,
                                    cap=cfg.attn_softcap)
        if cache is not None:            # prefill: fill the cache
            if isinstance(cache, PagedKVCache):
                # the serve engine prefills a dense batch-1 fragment and
                # page-scatters it into the pool (engine._insert); a direct
                # multi-token forward over the pool has no defined slot
                raise ValueError(
                    "paged KV caches take prefill via the engine's fragment "
                    "splice, not a multi-token forward")
            S = cache.k.shape[1]
            T = k.shape[1]
            k = k.astype(cache.k.dtype)
            v = v.astype(cache.v.dtype)
            if T >= S:                   # keep last S positions (ring)
                assert not prefix_len, (
                    "continued prefill needs prefix_len + suffix <= cache "
                    "capacity (the engine sizes fragments to whole prompts)")
                bidx = jnp.arange(k.shape[0])[:, None]
                k_keep, v_keep = k[:, -S:], v[:, -S:]
                pos_keep = positions[:, -S:].astype(jnp.int32)   # (B, S)
                # ring layout: slot = pos % S, per batch row
                slots = pos_keep % S
                k_c = jnp.zeros_like(cache.k).at[bidx, slots].set(k_keep)
                v_c = jnp.zeros_like(cache.v).at[bidx, slots].set(v_keep)
                pos_c = (jnp.full_like(cache.positions, -1)
                         .at[bidx, slots].set(pos_keep))
            else:                        # suffix rows land after the prefix
                k_c = lax.dynamic_update_slice_in_dim(
                    cache.k, k, prefix_len, axis=1)
                v_c = lax.dynamic_update_slice_in_dim(
                    cache.v, v, prefix_len, axis=1)
                pos_c = lax.dynamic_update_slice_in_dim(
                    cache.positions, positions.astype(jnp.int32), prefix_len,
                    axis=1)
            new_cache = KVCache(k_c, v_c, pos_c)
    o = o[:, :, :H_orig]   # drop padded q-head outputs before the projection
    return attn_out(o, p), new_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def ffn_swiglu(x, p, act="silu"):
    B, T, D = x.shape
    xf = x.reshape(B * T, D)
    if _fuse_epilogue():
        h = sa_dot(xf, p["wg"], act=act) * sa_dot(xf, p["wu"])
    else:
        h = act_fn(sa_dot(xf, p["wg"]), act) * sa_dot(xf, p["wu"])
    return sa_dot(h, p["wd"]).reshape(B, T, D)


def ffn_mlp(x, p, act="gelu"):
    B, T, D = x.shape
    xf = x.reshape(B * T, D)
    if _fuse_epilogue():
        return sa_dot(sa_dot(xf, p["w1"], act=act), p["w2"]).reshape(B, T, D)
    return sa_dot(act_fn(sa_dot(xf, p["w1"]), act), p["w2"]).reshape(B, T, D)
