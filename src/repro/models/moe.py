"""Mixture-of-Experts FFN: top-k routing, capacity + dropless dispatch, EP.

Two dispatches share one router:

* **Capacity (training)** — GShard-style static shapes: each expert
  processes its top-C tokens (C = ceil(k·T·capacity_factor / E)), gathered
  into a dense (B, E, C, D) buffer, run through batched expert GEMMs with
  the expert dim sharded over the `model` mesh axis (expert parallelism),
  and scatter-added back with the router combine weights. Compute scales
  with k·T (not E·T) but overflow tokens are *dropped*.

* **Dropless (serving)** — dense per-token expert compute: every expert runs
  every token and the k-sparse combine weights zero the non-routed pairs, so
  the result is the exact top-k router semantics with no capacity drops.
  Costs E/k× the capacity path's FLOPs — the right trade at decode shapes
  (T ∈ {1..8} per step), where dropping a user's token is unacceptable and
  the GEMMs are latency- not throughput-bound. Selected by the serving path
  (`model._sublayer` under a cache, optflag ``moe_dropless_serve``) or
  arch-wide via ``cfg.moe_dropless``.

Every contraction in both paths is a GEMM under the SA precision contract,
and all shapes are static — no ragged collectives, dry-run friendly.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.precision import sa_dot, sa_einsum
from repro.parallel import sharding as S_
from .layers import act_fn, ffn_swiglu


def router(x, w_router, k: int):
    """x: (B, T, D) → combine weights (B, T, E) (zero outside top-k,
    renormalized over the top-k) + aux losses."""
    B, T, D = x.shape
    logits = sa_dot(x.reshape(B * T, D), w_router).astype(jnp.float32)
    logits = logits.reshape(B, T, -1)
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    combine = jnp.sum(jax.nn.one_hot(topi, E, dtype=probs.dtype)
                      * topv[..., None], axis=-2)
    density = jnp.mean(jax.nn.one_hot(topi, E, dtype=jnp.float32),
                       axis=(0, 1, 2))
    mean_probs = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance": E * jnp.sum(density * mean_probs),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return combine, aux


def capacity(T: int, E: int, k: int, factor: float = 1.25) -> int:
    return max(1, min(T, math.ceil(T * k * factor / E)))


# dropless buffers are (B, E, Tc, F): chunking T bounds them during long
# serving prefills (decode steps are single-chunk). Per-token math is
# row-independent, so chunking never changes results.
DROPLESS_CHUNK_T = 128


def moe_ffn_dropless(x, p, cfg, act: str = "silu"):
    """Dropless dispatch (serving): exact top-k routing, no capacity drops.

    Dense per-token expert compute — every expert's activation for every
    token, with the k-sparse combine weights (zero outside the token's
    top-k) selecting and mixing. Exactly equals per-token
    ``Σ_{e∈topk(t)} w_e·E_e(x_t)`` at any T, so prefill+decode ≡ full
    forward for MoE archs. T is processed in chunks of `DROPLESS_CHUNK_T`
    so the (B, E, Tc, F) activations stay bounded on long prefills. See
    the module docstring for the FLOPs trade.
    """
    from jax import lax
    B, T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    combine, aux = router(x, p["router"], k)              # (B, T, E)
    tp = max(S_.axis_count("model"), 1)
    ep_axis = "model" if E % tp == 0 else None
    Tc = min(T, DROPLESS_CHUNK_T)
    pad = (-T) % Tc
    xp_ = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    cp = jnp.pad(combine, ((0, 0), (0, pad), (0, 0)))
    nc = (T + pad) // Tc
    xb = xp_.reshape(B, nc, Tc, D).swapaxes(0, 1)         # (nc, B, Tc, D)
    cb = cp.reshape(B, nc, Tc, E).swapaxes(0, 1)

    def chunk(_, xc_cc):
        xc, cc = xc_cc
        g = sa_einsum("btd,edf->betf", xc, p["wg"])
        u = sa_einsum("btd,edf->betf", xc, p["wu"])
        y = sa_einsum("betf,efd->betd", act_fn(g, act) * u, p["wd"])
        y = S_.constrain(y, "batch", ep_axis, None, None)
        return None, jnp.sum(
            y * cc.swapaxes(1, 2)[..., None].astype(y.dtype), axis=1)

    _, outs = lax.scan(chunk, None, (xb, cb))             # (nc, B, Tc, D)
    out = outs.swapaxes(0, 1).reshape(B, T + pad, D)[:, :T]
    if "shared_wg" in p:
        out = out + ffn_swiglu(x, {"wg": p["shared_wg"], "wu": p["shared_wu"],
                                   "wd": p["shared_wd"]}, act)
    return out.astype(x.dtype), aux


def moe_ffn(x, p, cfg, act: str = "silu", capacity_factor: float = 1.25,
            dropless: bool = False):
    """x: (B, T, D); p: router (D, E), wg/wu (E, D, F), wd (E, F, D),
    optional shared expert (shared_wg/wu/wd). ``dropless=True`` selects the
    exact serving dispatch (see `moe_ffn_dropless`)."""
    from repro.core import optflags
    if dropless:
        return moe_ffn_dropless(x, p, cfg, act)
    B, T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    C = capacity(T, E, k, capacity_factor)
    combine, aux = router(x, p["router"], k)              # (B, T, E)

    tp = max(S_.axis_count("model"), 1)
    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    if E % tp and optflags.enabled("pad_experts"):
        # pad experts to the TP axis: dummy experts receive zero combine
        # weight (never routed), so outputs are exact; the win is EP dispatch
        # instead of TP-inside-expert (granite: −60 % MoE collectives).
        E_pad = -(-E // tp) * tp
        combine = jnp.pad(combine, ((0, 0), (0, 0), (0, E_pad - E)))
        wg = jnp.pad(wg, ((0, E_pad - E), (0, 0), (0, 0)))
        wu = jnp.pad(wu, ((0, E_pad - E), (0, 0), (0, 0)))
        wd = jnp.pad(wd, ((0, E_pad - E), (0, 0), (0, 0)))
        E = E_pad

    # dispatch: per expert, its C highest-weight tokens (static shapes)
    gate, token_idx = jax.lax.top_k(combine.swapaxes(1, 2), C)  # (B, E, C)
    xe = jnp.take_along_axis(x[:, None], token_idx[..., None], axis=2)
    # expert GEMMs — E is the EP axis (sharded over `model` when divisible).
    # The explicit constraint keeps the dispatch buffer sharded like the
    # expert weights; without it the partitioner all-gathers the full expert
    # stack per device (observed: 160 GiB/dev on llama4 before this).
    ep_axis = "model" if E % tp == 0 else None
    xe = S_.constrain(xe, "batch", ep_axis, None, None)
    if ep_axis and E != cfg.num_experts:   # padded weights: pin EP layout
        wg = S_.constrain(wg, ep_axis, None, None)
        wu = S_.constrain(wu, ep_axis, None, None)
        wd = S_.constrain(wd, ep_axis, None, None)
    g = sa_einsum("becd,edf->becf", xe, wg)
    u = sa_einsum("becd,edf->becf", xe, wu)
    y = sa_einsum("becf,efd->becd", act_fn(g, act) * u, wd)
    y = S_.constrain(y, "batch", ep_axis, None, None)
    y = y * gate[..., None].astype(y.dtype)
    # combine: scatter-add expert outputs back to token positions
    out = jnp.zeros((B, T, D), y.dtype)
    bidx = jnp.arange(B)[:, None, None]
    out = out.at[bidx, token_idx].add(y)
    if "shared_wg" in p:
        out = out + ffn_swiglu(x, {"wg": p["shared_wg"], "wu": p["shared_wu"],
                                   "wd": p["shared_wd"]}, act)
    return out.astype(x.dtype), aux
