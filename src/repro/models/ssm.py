"""Mamba2 SSD (state-space duality) layer — chunked scan + O(1) decode.

Implements the SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060): within a
chunk the recurrence is computed in its quadratic "attention" form (all
GEMMs — SA-contract friendly), states are passed between chunks with a
`lax.scan`. Per-token decode updates the (H, P, N) state in O(1).

Layer structure follows Mamba2: in_proj → [z | x | B | C | dt], causal
depthwise conv on (x, B, C), SSD core, gated RMSNorm, out_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.precision import sa_dot, sa_einsum
from .layers import rmsnorm


def _segsum(a):
    """Stable 'segment sum' → lower-triangular L[t, s] = Σ_{s<j<=t} a_j."""
    T = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    L = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, L, -jnp.inf)


def ssd_chunked(x, dt, A, B_, C_, chunk: int, initial_state=None):
    """SSD core.

    x:  (B, T, H, P)   inputs per head
    dt: (B, T, H)      positive step sizes (post-softplus)
    A:  (H,)           negative decay rates
    B_: (B, T, N)      input projection (single group, broadcast over heads)
    C_: (B, T, N)      output projection
    returns y (B, T, H, P), final_state (B, H, P, N)
    """
    Bsz, T, H, P = x.shape
    N = B_.shape[-1]
    Q = min(chunk, T)
    pad = (-T) % Q
    if pad:   # zero-pad tail: dt=0 ⇒ decay=1, no input ⇒ state unaffected
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    T_pad, T_orig = T + pad, T
    T = T_pad
    nc = T // Q

    xb = x.reshape(Bsz, nc, Q, H, P)
    dtb = dt.reshape(Bsz, nc, Q, H)
    Bb = B_.reshape(Bsz, nc, Q, N)
    Cb = C_.reshape(Bsz, nc, Q, N)

    dA = dtb * A  # (B, nc, Q, H)  log-decay per step
    dA_cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum
    dA_total = dA_cum[:, :, -1]                         # (B, nc, H)

    # intra-chunk (quadratic / attention form): all contractions are GEMMs
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))   # (B, nc, H, Q, Q)
    scores = sa_einsum("bcqn,bckn->bcqk", Cb, Bb)       # (B, nc, Q, Q)
    M = scores[:, :, None] * Lmat.transpose(0, 1, 2, 3, 4)  # (B,nc,H,Q,Q)
    xdt = xb * dtb[..., None]                           # (B, nc, Q, H, P)
    y_intra = sa_einsum("bchqk,bckhp->bcqhp",
                        M.astype(x.dtype), xdt.astype(x.dtype))

    # chunk states: S_c = Σ_s exp(dA_total − dA_cum[s]) · B_s ⊗ (x_s·dt_s)
    decay_to_end = jnp.exp(dA_total[:, :, None] - dA_cum)     # (B, nc, Q, H)
    Sx = xdt * decay_to_end[..., None]
    S_chunk = sa_einsum("bcqn,bcqhp->bchpn", Bb.astype(x.dtype),
                        Sx.astype(x.dtype))              # (B, nc, H, P, N)

    # inter-chunk scan: carry running state across chunks
    def chunk_step(S_prev, inputs):
        S_c, dA_tot_c, C_c, dA_cum_c = inputs
        # contribution of the carried state to this chunk's outputs
        decay_in = jnp.exp(dA_cum_c)                     # (B, Q, H)
        y_c = sa_einsum("bqn,bhpn->bqhp", C_c.astype(x.dtype),
                        S_prev.astype(x.dtype))
        y_c = y_c * decay_in.transpose(0, 1, 2)[..., None]
        S_new = S_prev * jnp.exp(dA_tot_c)[:, :, None, None] + S_c
        return S_new, y_c

    S0 = (initial_state if initial_state is not None
          else jnp.zeros((Bsz, H, P, N), jnp.float32))
    S_final, y_inter = lax.scan(
        chunk_step, S0.astype(jnp.float32),
        (S_chunk.swapaxes(0, 1).astype(jnp.float32),
         dA_total.swapaxes(0, 1),
         Cb.swapaxes(0, 1),
         dA_cum.swapaxes(0, 1)))
    y = y_intra + y_inter.swapaxes(0, 1).reshape(Bsz, nc, Q, H, P).astype(y_intra.dtype)
    return y.reshape(Bsz, T, H, P)[:, :T_orig], S_final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """O(1) recurrent update. state: (B, H, P, N); x_t: (B, H, P);
    dt_t: (B, H); B_t/C_t: (B, N)."""
    dA = jnp.exp(dt_t * A)                                    # (B, H)
    dBx = (dt_t[..., None] * x_t)[..., None] * B_t[:, None, None, :]
    state = state * dA[..., None, None] + dBx
    y = sa_einsum("bn,bhpn->bhp", C_t.astype(x_t.dtype),
                  state.astype(x_t.dtype))
    return state, y


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv1d. x: (B, T, D); w: (KW, D). Returns (y, tail)
    where tail is the last KW-1 inputs (decode cache)."""
    KW = w.shape[0]
    if cache is not None:
        xp = jnp.concatenate([cache, x], axis=1)
    else:
        xp = jnp.pad(x, ((0, 0), (KW - 1, 0), (0, 0)))
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(KW))
    tail = xp[:, -(KW - 1):] if KW > 1 else None
    return jax.nn.silu(y), tail


def mamba2_block(x, p, cfg, state=None, conv_cache=None):
    """Full Mamba2 mixer. x: (B, T, D). If `state` is given and T == 1 runs
    the recurrent decode path. Returns (y, (state, conv_cache))."""
    B, T, D = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    din = cfg.d_inner
    zxbcdt = sa_dot(x.reshape(B * T, D), p["in_proj"]).reshape(B, T, -1)
    z, xin, B_, C_, dt = jnp.split(
        zxbcdt, [din, 2 * din, 2 * din + N, 2 * din + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, T, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (H,)

    conv_in = jnp.concatenate([xin, B_, C_], axis=-1)
    conv_out, conv_tail = _causal_conv(conv_in, p["conv_w"], conv_cache)
    xin, B_, C_ = jnp.split(conv_out, [din, din + N], axis=-1)
    xh = xin.reshape(B, T, H, P)

    if state is not None and T == 1:
        state, y = ssd_decode_step(state, xh[:, 0], dt[:, 0], A,
                                   B_[:, 0], C_[:, 0])
        y = y[:, None]                                           # (B, 1, H, P)
        new_state = state
    else:
        y, new_state = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk,
                                   initial_state=state)
    y = y.reshape(B, T, din) + xin * p["D_skip"]
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_w"], cfg.norm_eps)
    out = sa_dot(y.reshape(B * T, din), p["out_proj"]).reshape(B, T, D)
    return out, (new_state, conv_tail)
