"""Phi-3-medium 14B — dense RoPE/SwiGLU/GQA [arXiv:2404.14219]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10, head_dim=128,
    d_ff=17920, vocab_size=100352,
    pad_attn_train=True,   # 40H/10KVH replicates 16× without padding
)
