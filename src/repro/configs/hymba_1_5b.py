"""Hymba-1.5B — hybrid parallel attention ∥ Mamba heads [arXiv:2411.13676].

Parallel-head fusion (mean of per-branch RMSNorms), SWA for most layers with
periodic global layers. The published model places global attention at layers
{1, 17, 32}; the scan-superblock layout here uses a period-16 pattern (global
at layers 0 and 16) — noted in DESIGN.md §5.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid", hybrid=True,
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001,
    attn_pattern=("global",) + ("local",) * 15, window=2048,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2,
)
