"""Whisper-tiny — enc-dec backbone; conv frontend is a STUB (input_specs
supplies precomputed 1500×384 frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    num_layers=4, encoder_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
    d_ff=1536, vocab_size=51865, frontend_tokens=1500,
    norm="layernorm", act="gelu",
)
