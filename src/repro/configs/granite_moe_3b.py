"""Granite-3.0 MoE 3B-A800M — 40 experts, top-8 [hf:ibm-granite]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    num_experts=40, experts_per_token=8, moe_every=1,
    pad_attn_train=True,   # measured: 18.1→10.9 s train collectives (§Perf)
)
