"""Gemma2-9B — alternating local:global attention, logit softcaps
[arXiv:2408.00118]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    attn_pattern=("local", "global"), window=4096,
    attn_softcap=50.0, final_softcap=30.0,
    act="gelu", embed_scale=True, tie_embeddings=True,
)
