"""Pixtral-12B — ViT frontend STUB (input_specs supplies patch embeddings)
over a mistral-nemo-style decoder backbone [hf:mistralai/Pixtral-12B]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    frontend_tokens=256, rope_theta=1000000.0,
)
