"""Llama-4 Maverick 400B-A17B — 128-expert top-1 MoE, early fusion.

MoE on every second layer with a shared expert (hf Llama-4
`interleave_moe_layer_step=2`); dense layers use a 16384-wide FFN so the
total lands at ~400 B with ~17 B active (DESIGN.md §5). Requires FSDP
(params 2-D sharded over (pod, data) × model).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, d_ff_dense=16384, vocab_size=202048,
    num_experts=128, experts_per_token=1, moe_every=2, shared_expert=True,
    rope_theta=500000.0, fsdp=True,
    pad_attn_train=True,   # measured: improves train collectives (§Perf)
)
