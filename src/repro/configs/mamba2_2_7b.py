"""Mamba2-2.7B — attention-free SSD [arXiv:2405.21060]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
)
