"""Architecture registry: the 10 assigned archs + reduced smoke variants."""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, SHAPES, SHAPES_BY_NAME, ShapeCfg

from .hymba_1_5b import CONFIG as HYMBA
from .granite_moe_3b import CONFIG as GRANITE
from .llama4_maverick import CONFIG as LLAMA4
from .mamba2_2_7b import CONFIG as MAMBA2
from .whisper_tiny import CONFIG as WHISPER
from .phi3_medium import CONFIG as PHI3
from .qwen2_5_14b import CONFIG as QWEN25
from .gemma2_9b import CONFIG as GEMMA2
from .gemma3_12b import CONFIG as GEMMA3
from .pixtral_12b import CONFIG as PIXTRAL

REGISTRY: dict[str, ArchConfig] = {c.name: c for c in (
    HYMBA, GRANITE, LLAMA4, MAMBA2, WHISPER, PHI3, QWEN25, GEMMA2, GEMMA3,
    PIXTRAL)}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str) -> ArchConfig:
    try:
        return REGISTRY[name]
    except KeyError as e:
        raise ValueError(f"unknown arch {name!r}; have {sorted(REGISTRY)}") from e


def reduced_config(name: str, layers_per_period: int = 1,
                   width: int = 1) -> ArchConfig:
    """Smoke-test variant: same family/structure, tiny dims.

    Keeps the structural pattern (attn_pattern, moe cadence, hybrid/enc-dec)
    but shrinks width/depth/experts/vocab so one CPU train step is cheap.
    `width` scales d_model/d_ff (×width) past the dispatch-bound floor —
    at width 1 every forward costs about the same wall time regardless of
    depth, so experiments about *compute* ratios (e.g. the early-exit
    draft's depth saving, DESIGN.md §9) need width ≥ ~4 to measure
    anything but op-dispatch overhead.
    """
    full = get_config(name)
    period = full.stack_period
    hd = 16
    n_heads = max(2, min(full.num_heads, 4))
    n_kv = max(1, min(full.num_kv_heads, 2))
    changes = dict(
        name=full.name + "-smoke",
        num_layers=period * layers_per_period,
        d_model=64 * width, head_dim=hd,
        num_heads=n_heads, num_kv_heads=n_kv,
        d_ff=0 if full.family == "ssm" else 128 * width,
        d_ff_dense=128 * width if full.d_ff_dense else 0,
        vocab_size=503,  # odd on purpose: catches divisibility assumptions
        window=min(full.window, 8) if full.window else 0,
        ssm_state=16 if full.ssm_state else 0,
        ssm_head_dim=16 if full.ssm_state else 64,
        ssm_chunk=8,
        num_experts=min(full.num_experts, 8),
        experts_per_token=min(full.experts_per_token, 2),
        encoder_layers=2 if full.encoder_layers else 0,
        frontend_tokens=16 if full.frontend_tokens else 0,
        fsdp=False,
    )
    return dataclasses.replace(full, **changes)


__all__ = ["REGISTRY", "ARCH_NAMES", "get_config", "reduced_config",
           "ArchConfig", "SHAPES", "SHAPES_BY_NAME", "ShapeCfg"]
