"""Gemma3-12B — 5:1 local:global, 128k context [hf:google/gemma-3]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    attn_pattern=("local",) * 5 + ("global",), window=1024,
    act="gelu", embed_scale=True, tie_embeddings=True,
    rope_theta=1000000.0,
)
