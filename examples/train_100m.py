"""Train a ~100M-parameter LM (qwen2.5 structural twin) for a few hundred
steps with checkpointing, preemption handling and straggler watchdog.

Default invocation is CPU-sized (small batch, short run); pass --full for
the real 100M × several-hundred-step recipe (hours on CPU, minutes on a
TPU host):

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import main as train_main
import repro.configs as C


def make_100m():
    base = get_config("qwen2.5-14b")
    return dataclasses.replace(
        base, name="qwen2.5-100m", num_layers=8, d_model=768, num_heads=12,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768, fsdp=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    cfg = make_100m()
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    # register so the generic train driver can find it
    C.REGISTRY[cfg.name] = cfg

    steps = args.steps or (300 if args.full else 30)
    batch = 16 if args.full else 4
    seq = 1024 if args.full else 128
    train_main([
        "--arch", cfg.name, "--steps", str(steps), "--batch", str(batch),
        "--seq", str(seq), "--ckpt-dir", "/tmp/ckpt_100m",
        "--ckpt-every", "100", "--accum", "2", "--resume",
        "--log", "/tmp/train_100m.jsonl",
    ])


if __name__ == "__main__":
    main()
