"""End-to-end continuous-batching example: a request stream through the
slot scheduler + chunked-decode engine.

Requests with mixed prompt lengths and token budgets share a fixed pool of
decode slots; a finished slot is refilled from the queue while the other
slots keep decoding at their own per-slot positions — no wave barriers.

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import SlotScheduler


def main():
    cfg = reduced_config("gemma3-12b", layers_per_period=1)
    params = M.init_params(jax.random.key(0), cfg)
    batch, cache_len = 4, 48
    engine = ServeEngine(cfg, params, batch=batch, cache_len=cache_len,
                         eos_id=-1, sync_every=4)   # no eos in synthetic vocab

    # 12 synthetic requests, mixed prompt lengths and budgets
    rng = np.random.default_rng(0)
    sched = SlotScheduler(batch, eos_id=-1)
    for i in range(12):
        plen = (8, 16)[i % 2]
        sched.submit(rng.integers(0, cfg.vocab_size, plen),
                     max_new_tokens=(12, 24)[i % 2])
    summary = engine.serve(sched)

    for r in sorted(sched.finished, key=lambda r: r.rid):
        print(f"req {r.rid:2d} slot {r.slot} prompt {r.prompt_len:3d} "
              f"gen {r.n_generated:3d} ttft {r.ttft:.2f}s "
              f"sample {r.tokens[:6]}")
    print(" ".join(f"{k}={v}" for k, v in summary.items()))


if __name__ == "__main__":
    main()
