"""End-to-end serving driver: batched requests through the decode engine.

Serves a small (structural-twin) model with continuous slot refill: finished
requests leave, queued requests take their slot with a fresh prefill — the
static-batch analogue of continuous batching.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    cfg = reduced_config("gemma3-12b", layers_per_period=1)
    params = M.init_params(jax.random.key(0), cfg)
    batch, plen, new = 4, 16, 24
    engine = ServeEngine(cfg, params, batch=batch, cache_len=plen + new,
                         eos_id=-1)   # no eos in synthetic vocab

    # a queue of 12 synthetic requests served 4 at a time
    rng = np.random.default_rng(0)
    queue = [rng.integers(0, cfg.vocab_size, plen).tolist() for _ in range(12)]
    served = 0
    t0 = time.time()
    while queue:
        wave = [queue.pop(0) for _ in range(min(batch, len(queue)))]
        while len(wave) < batch:          # pad the last wave
            wave.append(wave[-1])
        prompts = jnp.asarray(np.array(wave), jnp.int32)
        out = engine.generate(prompts, max_new_tokens=new)
        served += len(wave)
        print(f"wave done: {out.shape[0]} seqs × {out.shape[1]} tokens; "
              f"sample {out[0, :8].tolist()}")
    dt = time.time() - t0
    print(f"served {served} requests, {served*new} tokens in {dt:.1f}s "
          f"({served*new/dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
