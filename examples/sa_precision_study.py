"""The paper's core demonstration, end to end:

1. bit-exactness — the skewed pipeline's speculative exponent algebra gives
   *identical* results to the baseline pipeline (§III.B), across formats;
2. latency/energy — the cycle model reproduces the §IV headline numbers;
3. precision ladder — the SA arithmetic contract (sa_dot) applied to a real
   model forward pass: fp32 vs bf16 vs fp8 logits drift.

    PYTHONPATH=src python examples/sa_precision_study.py
"""
import jax
import numpy as np

from repro.configs import reduced_config
from repro.core import PrecisionPolicy, use_policy
from repro.core import chained_fma as cf
from repro.core import energy as E
from repro.core.fpformats import BF16, FP8_E4M3, FP8_E5M2, quantize_np
from repro.core.systolic import BASELINE, SKEWED, SAConfig, gemm_latency
from repro.models import model as M


def main():
    print("== 1. skew ≡ baseline (bit-exact), per format ==")
    rng = np.random.default_rng(0)
    for fmt in (BF16, FP8_E4M3, FP8_E5M2):
        a = quantize_np(rng.standard_normal((32, 64)), fmt)
        w = quantize_np(rng.standard_normal((64, 24)), fmt)
        b = cf.matmul_emulated(a, w, fmt, "baseline")
        s = cf.matmul_emulated(a, w, fmt, "skewed")
        exact = np.array_equal(b.view(np.uint32), s.view(np.uint32))
        print(f"  {fmt.name:10s} bit-exact: {exact}")

    print("\n== 2. latency & energy (128×128 SA @ 1 GHz) ==")
    for M_, K, N, tag in ((49, 1024, 1024, "late CNN layer"),
                          (12544, 27, 32, "early CNN layer"),
                          (4096, 5120, 5120, "LLM GEMM")):
        cb = gemm_latency(M_, K, N, SAConfig(pipeline=BASELINE))
        cs = gemm_latency(M_, K, N, SAConfig(pipeline=SKEWED))
        print(f"  {tag:16s} {M_}x{K}x{N}: {cb} → {cs} cycles "
              f"({100*(1-cs/cb):.1f}% faster)")
    for net, paper in (("mobilenet", (16, 8)), ("resnet50", (21, 11))):
        t = E.network_totals(net)
        print(f"  {net:10s} latency −{t['latency_saving']:.1%} "
              f"(paper −{paper[0]}%), energy −{t['energy_saving']:.1%} "
              f"(paper −{paper[1]}%)")

    print("\n== 3. the SA contract inside a real model ==")
    cfg = reduced_config("qwen2.5-14b")
    params = M.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    ref = None
    for fmt in ("fp32", "bf16", "fp8_e5m2", "fp8_e4m3"):
        with use_policy(PrecisionPolicy(input_format=fmt)):
            logits, _, _ = M.forward(params, cfg, toks)
        x = np.asarray(logits[..., :cfg.vocab_size])
        if ref is None:
            ref = x
            print(f"  {fmt:10s} (reference)")
        else:
            rel = np.abs(x - ref).max() / np.abs(ref).max()
            agree = (x.argmax(-1) == ref.argmax(-1)).mean()
            print(f"  {fmt:10s} max rel dev {rel:.2e}, "
                  f"top-1 agreement {agree:.1%}")


if __name__ == "__main__":
    main()
