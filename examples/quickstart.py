"""Quickstart: train a tiny LM end-to-end on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data.pipeline import SyntheticLM
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.step import make_train_step
from repro.train.train_state import init_state


def main():
    cfg = reduced_config("gemma2-9b")       # tiny structural twin
    print(f"arch={cfg.name} layers={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.param_count()/1e6:.2f}M")
    steps = 40
    opt = AdamW(schedule=warmup_cosine(3e-3, 4, steps), weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, accum_steps=2))
    state = init_state(jax.random.key(0), cfg, opt)
    data = SyntheticLM(cfg.vocab_size, seq_len=64, batch_per_host=8,
                       structured=True)   # learnable arithmetic sequences
    first = last = None
    for i, batch in zip(range(steps), data):
        state, m = step(state, {k: jnp.asarray(v) for k, v in batch.items()})
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}")
    print(f"loss: {first:.4f} → {last:.4f} "
          f"({'improved' if last < first else 'check config'})")


if __name__ == "__main__":
    main()
