"""Approximate-normalization arithmetic tiers (arxiv 2408.11997 model).

Covers the whole vertical: the numpy coarse-LZA oracle (chained_fma.approx_*),
its on-device twin (fp_emu mode="approx"), the MXU-path model (sa_matmul
guard-bit truncation), the policy plumbing (PrecisionPolicy.mode across
backends), the scheduler's tier-affine admission + per-(tier, mode) token
accounting, the engine's all-bulk chunk rule + divergence probe, and the
per-tier energy model. Also pins the shared E_ZERO sentinel (the numeric-
consistency bugfix this PR ships) and the energy zero-guards.
"""
import dataclasses

import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or mini-runner shim

import jax
import jax.numpy as jnp

from repro.core import chained_fma as cf
from repro.core import energy
from repro.core.fpformats import BF16, quantize_np
from repro.core.precision import PrecisionPolicy, sa_dot, use_policy
from repro.kernels import fp_emu
from repro.kernels.sa_matmul import (APPROX_DROP_BITS, sa_matmul_pallas,
                                     truncate_mantissa)
from repro.serve.scheduler import SlotScheduler


def bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


# ---------------------------------------------------------------------------
# numpy oracle: coarse-LZA chain
# ---------------------------------------------------------------------------

def _random_chain(rng, style: int):
    k = int(rng.integers(1, 64))
    if style == 0:
        a, w = rng.standard_normal(k), rng.standard_normal(k)
    elif style == 1:   # wide exponent swings + sign flips (cancellation)
        a = 2.0 ** rng.integers(-20, 20, k) * rng.choice([-1.0, 1.0], k)
        w = rng.standard_normal(k)
    else:              # badly scaled
        a, w = rng.standard_normal(k) * 1e4, rng.standard_normal(k) * 1e-4
    a = quantize_np(np.asarray(a, np.float32), BF16)
    w = quantize_np(np.asarray(w, np.float32), BF16)
    return a, w


@settings(max_examples=150, deadline=None)
@given(st.integers(0, 2 ** 32 - 1))
def test_approx_differs_only_below_guard_threshold(seed):
    """The coarse LZA leaves ≤ APPROX_COARSE−1 bits of normalization debt,
    so each PE's alignment truncation cuts at most 2^APPROX_COARSE ulps
    (of the largest running partial) higher than the exact pipeline —
    total divergence bounded by (K+2)·2^APPROX_COARSE·ulp(anchor).
    Empirically the worst observed ratio is ~1 % of this bound."""
    rng = np.random.default_rng(seed)
    a, w = _random_chain(rng, seed % 3)
    ac, wc = a.reshape(-1, 1), w.reshape(-1, 1)
    ex = cf.skewed_chain(ac, wc, BF16).astype(np.float64)
    ap = cf.approx_chain(ac, wc, BF16).astype(np.float64)
    prods = a.astype(np.float64) * w.astype(np.float64)
    run = np.abs(np.cumsum(prods))
    anchor = max(np.max(run, initial=0.0),
                 np.max(np.abs(prods), initial=0.0))
    if anchor == 0.0:
        np.testing.assert_array_equal(ex, ap)
        return
    bound = ((len(a) + 2) * 2.0 ** cf.APPROX_COARSE
             * float(np.spacing(np.float32(anchor))))
    assert float(np.abs(ex - ap)[0]) <= bound


def test_approx_exact_when_no_alignment_truncation():
    """Equal-exponent products never shift bits past the cutoff, so the
    coarse shifter loses nothing: bit-identical to the exact pipelines."""
    a = np.full((1, 16), 1.5, np.float32)
    w = np.full((16, 1), 2.0, np.float32)
    ex = cf.matmul_emulated(a, w, BF16, "skewed")
    ap = cf.matmul_emulated(a, w, BF16, "approx")
    np.testing.assert_array_equal(bits(ex), bits(ap))
    assert ap[0, 0] == np.float32(48.0)


def test_matmul_emulated_rejects_unknown_pipeline():
    a = np.ones((2, 2), np.float32)
    with pytest.raises(ValueError, match="pipeline"):
        cf.matmul_emulated(a, a, BF16, "turbo")


# ---------------------------------------------------------------------------
# kernels: fp_emu twin + MXU-path truncation model
# ---------------------------------------------------------------------------

def _bf16_pair(rng, m=8, k=16, n=8):
    a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
    return a.astype(jnp.float32), w.astype(jnp.float32)


def test_fp_emu_approx_matches_numpy_oracle():
    a, w = _bf16_pair(np.random.default_rng(0))
    got = np.asarray(fp_emu.fma_emu_matmul(a, w, "bf16", mode="approx"))
    want = cf.matmul_emulated(np.asarray(a), np.asarray(w), BF16, "approx")
    np.testing.assert_array_equal(bits(got), bits(want))
    # and the exact mode stays the skewed pipeline
    got0 = np.asarray(fp_emu.fma_emu_matmul(a, w, "bf16", mode="exact"))
    want0 = cf.matmul_emulated(np.asarray(a), np.asarray(w), BF16, "skewed")
    np.testing.assert_array_equal(bits(got0), bits(want0))


def test_fp_emu_rejects_unknown_mode():
    a = jnp.ones((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="mode"):
        fp_emu.fma_emu_matmul(a, a, "bf16", mode="fast")


def test_e_zero_sentinel_shared():
    """fp_emu must import the zero sentinel from the numpy twin — two
    drifting definitions would silently break the bit-exactness contract
    (this PR fixes exactly that: fp_emu had its own -100000)."""
    assert fp_emu.E_ZERO is cf.E_ZERO
    assert cf.E_ZERO == -(1 << 20)
    import ast
    import inspect
    tree = ast.parse(inspect.getsource(fp_emu))
    own = [n.targets[0].id for n in ast.walk(tree)
           if isinstance(n, ast.Assign)
           and isinstance(n.targets[0], ast.Name)
           and n.targets[0].id == "E_ZERO"]
    assert not own, "fp_emu redefines E_ZERO instead of importing it"


def test_pallas_approx_is_guard_bit_truncation():
    a, w = _bf16_pair(np.random.default_rng(1), m=8, k=32, n=8)
    ex = sa_matmul_pallas(a, w, bm=8, bn=8, bk=32, interpret=True)
    ap = sa_matmul_pallas(a, w, bm=8, bn=8, bk=32, interpret=True,
                          mode="approx")
    ref = truncate_mantissa(
        jnp.dot(a, w, preferred_element_type=jnp.float32))
    np.testing.assert_array_equal(bits(ap), bits(ref))
    # truncation zeroes exactly the low APPROX_DROP_BITS mantissa bits
    assert not np.any(bits(ap) & ((1 << APPROX_DROP_BITS) - 1))
    assert np.any(bits(ex) != bits(ap))


def test_sa_matmul_pallas_rejects_unknown_mode():
    a = jnp.ones((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="mode"):
        sa_matmul_pallas(a, a, interpret=True, mode="fast")


def test_sa_dot_approx_backend_parity():
    """mode="approx" must mean the same arithmetic on xla and pallas."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((4, 32)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((32, 8)), jnp.float32)
    ys = {b: np.asarray(sa_dot(a, w, PrecisionPolicy(backend=b,
                                                     mode="approx")))
          for b in ("xla", "pallas")}
    np.testing.assert_array_equal(bits(ys["xla"]), bits(ys["pallas"]))
    y_exact = np.asarray(sa_dot(a, w, PrecisionPolicy()))
    assert np.any(bits(y_exact) != bits(ys["xla"]))


def test_backward_gemms_stay_exact():
    """mode="approx" truncates the forward only: grads through the pallas
    kernel match the exact-mode grads bit-for-bit (training never runs on
    the bulk datapath)."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)

    def loss(mode):
        def f(a_, w_):
            return jnp.sum(sa_matmul_pallas(a_, w_, bm=8, bn=8, bk=16,
                                            interpret=True, mode=mode))
        return jax.grad(f, argnums=(0, 1))(a, w)

    (da0, dw0), (da1, dw1) = loss("exact"), loss("approx")
    np.testing.assert_array_equal(bits(da0), bits(da1))
    np.testing.assert_array_equal(bits(dw0), bits(dw1))


def test_policy_validates_mode():
    with pytest.raises(ValueError, match="mode"):
        PrecisionPolicy(mode="fast")


# ---------------------------------------------------------------------------
# scheduler: tiers
# ---------------------------------------------------------------------------

def test_submit_rejects_unknown_tier():
    s = SlotScheduler(1)
    with pytest.raises(ValueError, match="tier"):
        s.submit([1, 2], 4, tier="gold")


def test_tier_affine_admission_phase_separates():
    s = SlotScheduler(2)
    r0 = s.submit([1], 4, tier="premium")
    s.submit([1], 4, tier="bulk")
    r2 = s.submit([1], 4, tier="premium")
    r3 = s.submit([1], 4, tier="bulk")
    assert s.admit(0, 0.0) is r0            # FIFO head (empty batch)
    assert s.admit(1, 0.0) is r2            # tier-affine: skips the bulk head
    assert s.tier_affine_picks == 1
    # drain the premiums; the bulk pair should then batch together
    for slot in (0, 1):
        req = s.slots[slot].req
        s._finish(s.slots[slot], req, "eos", 1.0)
    b1 = s.admit(0, 1.0)
    b2 = s.admit(1, 1.0)
    assert (b1.tier, b2.tier) == ("bulk", "bulk")
    assert b2 is r3
    assert s.num_active() == 2


def test_tier_affinity_never_admits_future_arrivals():
    s = SlotScheduler(2)
    s.submit([1], 4, tier="premium", arrival_time=0.0)
    s.submit([1], 4, tier="bulk", arrival_time=0.0)
    s.submit([1], 4, tier="premium", arrival_time=99.0)  # not arrived
    assert s.admit(0, 0.0).tier == "premium"
    # only the bulk head has arrived; the premium match is in the future
    assert s.admit(1, 0.0).tier == "bulk"


def test_tier_mode_token_accounting():
    s = SlotScheduler(1, eos_id=-1)
    s.submit([1, 2], 6, tier="bulk")
    s.admit(0, 0.0)
    s.start(0, first_token=7, now=0.0)      # prefill token: always exact
    s.observe(np.array([[5], [5]]), 1.0, mode="approx")
    s.observe(np.array([[5]]), 2.0, mode="exact")
    assert s.tier_mode_tokens == {("bulk", "exact"): 2,
                                  ("bulk", "approx"): 2}
    summ = s.summary()
    assert summ["tier_mode_tokens"] == {"bulk/approx": 2, "bulk/exact": 2}


def test_all_premium_summary_has_no_tier_section():
    s = SlotScheduler(1, eos_id=-1)
    s.submit([1], 2)
    s.admit(0, 0.0)
    s.start(0, first_token=3, now=0.0)
    s.observe(np.array([[4]]), 1.0)
    assert "tier_mode_tokens" not in s.summary()


# ---------------------------------------------------------------------------
# energy: approximate design point + the zero-guard bugfix
# ---------------------------------------------------------------------------

def test_network_totals_zero_guard(monkeypatch):
    from repro.core import workloads as wl
    monkeypatch.setitem(wl.WORKLOADS, "empty", lambda: [])
    out = energy.network_totals("empty")
    assert out["latency_saving"] == 0.0
    assert out["energy_saving"] == 0.0


def test_decode_token_energy_ordering():
    e = {d: energy.decode_token_energy_uj(10 ** 9, d)
         for d in (energy.BASELINE, energy.SKEWED, energy.SKEWED_APPROX)}
    assert e[energy.SKEWED_APPROX] < e[energy.BASELINE] < e[energy.SKEWED]
    saving = 1 - e[energy.SKEWED_APPROX] / e[energy.SKEWED]
    assert 0.05 < saving < 0.15              # modeled ~10 % per-token
    assert energy.decode_token_energy_uj(0) == 0.0


def test_tier_energy_summary_accounting():
    counts = {("premium", "exact"): 90, ("bulk", "approx"): 30,
              ("bulk", "exact"): 10}
    out = energy.tier_energy_summary(counts, macs_per_token=10 ** 6)
    assert out["tokens"] == 130
    assert 0 < out["energy_saving"] < 0.1    # only 30/130 tokens approx
    assert out["energy_uj"] < out["energy_uj_all_exact"]
    # string-keyed input (a scheduler summary round-trip) agrees
    out2 = energy.tier_energy_summary(
        {f"{t}/{m}": n for (t, m), n in counts.items()},
        macs_per_token=10 ** 6)
    assert out2 == out
    # all-exact stream: zero saving, not a division error
    out3 = energy.tier_energy_summary({("premium", "exact"): 5},
                                      macs_per_token=10 ** 6)
    assert out3["energy_saving"] == 0.0
    assert energy.tier_energy_summary({}, 10 ** 6)["energy_saving"] == 0.0


# ---------------------------------------------------------------------------
# engine: chunk-mode rule, premium exactness, divergence probe
# ---------------------------------------------------------------------------

_FP32 = PrecisionPolicy(input_format="fp32")


@pytest.fixture(scope="module")
def tiny_engine():
    from repro.configs import reduced_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    cfg = dataclasses.replace(reduced_config("qwen2.5-14b"), remat=False)
    with use_policy(_FP32):
        params = M.init_params(jax.random.key(0), cfg)
    return ServeEngine(cfg, params, batch=2, cache_len=24, eos_id=-1,
                       sync_every=2)


def _run_stream(engine, tiers):
    sched = SlotScheduler(engine.batch, eos_id=-1)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, engine.cfg.vocab_size, 4) for _ in tiers]
    for prompt, tier in zip(prompts, tiers):
        sched.submit(prompt, max_new_tokens=6, tier=tier)
    with use_policy(_FP32):
        summary = engine.serve(sched, greedy=True)
    return sched, summary


def test_mixed_stream_runs_approx_chunks(tiny_engine):
    sched, summary = _run_stream(
        tiny_engine, ["premium", "bulk", "premium", "bulk"])
    assert summary["requests"] == 4
    assert summary.get("chunks_approx", 0) > 0
    tmt = summary["tier_mode_tokens"]
    assert tmt.get("bulk/approx", 0) > 0
    # the chunk-mode rule: premium NEVER decodes on the approximate path
    assert "premium/approx" not in tmt
    # per-tier energy falls out of the accounting
    e = energy.tier_energy_summary(sched.tier_mode_tokens,
                                   tiny_engine.macs_per_token())
    assert e["energy_saving"] > 0


def test_premium_tokens_identical_under_mixed_stream(tiny_engine):
    """The exact tier's outputs must be byte-identical whether or not bulk
    traffic shares the engine (greedy decode, row-independent batch)."""
    tiers = ["premium", "bulk", "premium", "bulk"]
    mixed, _ = _run_stream(tiny_engine, tiers)
    allprem, _ = _run_stream(tiny_engine, ["premium"] * 4)
    for rm, rp, tier in zip(
            sorted(mixed.finished, key=lambda r: r.rid),
            sorted(allprem.finished, key=lambda r: r.rid), tiers):
        assert rm.prompt == rp.prompt
        if tier == "premium":
            assert rm.tokens == rp.tokens


def test_divergence_probe_bounds(tiny_engine):
    rng = np.random.default_rng(9)
    with use_policy(_FP32):
        probe = tiny_engine.divergence_probe(
            rng.integers(0, tiny_engine.cfg.vocab_size, 4), steps=4)
    # the modes must actually differ (a shared jit trace would report 0 —
    # the failure mode this probe's fresh-closure jitting exists to avoid)
    assert probe["max_ulp"] > 0
    # documented bound (DESIGN.md §6): guard-bit truncation through a
    # reduced-depth model stays within 2^12 ulp on the logits
    assert probe["max_ulp"] <= 4096
    assert probe["kl_mean"] < 1e-4
