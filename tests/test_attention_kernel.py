"""Pallas flash-attention kernel vs naive oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import sa_attention


def naive(q, k, v, causal=True, window=0, cap=0.0):
    B, H, T, hd = q.shape
    KVH, S = k.shape[1], k.shape[2]
    g = H // KVH
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kk) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp, kp = jnp.arange(T), jnp.arange(S)
    ok = jnp.ones((T, S), bool)
    if causal:
        ok &= qp[:, None] >= kp[None, :]
    if window:
        ok &= qp[:, None] - kp[None, :] < window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    return jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vv)


@pytest.mark.parametrize("kw", [
    dict(), dict(window=7), dict(cap=4.0), dict(causal=False),
    dict(window=5, cap=2.0)],
    ids=["causal", "window", "softcap", "bidir", "win+cap"])
@pytest.mark.parametrize("shape", [
    (1, 2, 2, 16, 16, 8),      # MHA
    (2, 4, 2, 32, 32, 16),     # GQA
    (1, 6, 3, 24, 48, 8),      # GQA, T != S, non-pow2
])
def test_sa_attention_vs_naive(kw, shape):
    B, H, KVH, T, S, hd = shape
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, T, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, KVH, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, KVH, S, hd), jnp.float32)
    out = sa_attention(q, k, v, bq=8, bkv=8, **kw)
    ref = naive(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_sa_attention_block_shape_invariance():
    B, H, KVH, T, hd = 1, 2, 1, 64, 8
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (B, H, T, hd))
    k = jax.random.normal(ks[1], (B, KVH, T, hd))
    v = jax.random.normal(ks[2], (B, KVH, T, hd))
    outs = [np.asarray(sa_attention(q, k, v, bq=bq, bkv=bkv))
            for bq, bkv in ((8, 8), (16, 32), (64, 64))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-6, atol=2e-6)


def test_sa_attention_matches_model_blockwise():
    """Kernel ≡ the model's jnp blockwise attention (the path it replaces)."""
    from repro.core import PrecisionPolicy, use_policy
    from repro.models.layers import blockwise_attention
    with use_policy(PrecisionPolicy(input_format="fp32")):
        B, H, KVH, T, hd = 2, 4, 2, 32, 8
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (B, T, H, hd))
        k = jax.random.normal(ks[1], (B, T, KVH, hd))
        v = jax.random.normal(ks[2], (B, T, KVH, hd))
        jnp_out = blockwise_attention(q, k, v, causal=True, window=6,
                                      block_q=8, block_kv=8)
        krn_out = sa_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3),
                               causal=True, window=6, bq=8, bkv=8)
        np.testing.assert_allclose(np.asarray(krn_out.transpose(0, 2, 1, 3)),
                                   np.asarray(jnp_out), rtol=3e-5, atol=3e-5)


def test_prefill_via_kernel_matches_jnp_path():
    """Flag-gated serving prefill through the Pallas kernel ≡ jnp path."""
    import dataclasses
    from repro.configs import reduced_config
    from repro.core import PrecisionPolicy, use_policy, optflags
    from repro.models import model as M

    cfg = dataclasses.replace(reduced_config("gemma2-9b"), remat=False)
    with use_policy(PrecisionPolicy(input_format="fp32")):
        params = M.init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 12), 0,
                                  cfg.vocab_size)
        cache_a = M.init_cache(cfg, 2, 16, dtype=jnp.float32)
        logits_a, cache_a, _ = M.forward(params, cfg, toks, cache=cache_a)
        old = optflags.FLAGS["pallas_attention"]
        try:
            optflags.set_flag("pallas_attention", True)
            cache_b = M.init_cache(cfg, 2, 16, dtype=jnp.float32)
            logits_b, cache_b, _ = M.forward(params, cfg, toks, cache=cache_b)
        finally:
            optflags.set_flag("pallas_attention", old)
        np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                                   rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
