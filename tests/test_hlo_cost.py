"""The trip-count-aware HLO cost analyzer (roofline numerator) against
hand-counted programs — this is what §Roofline's FLOP numbers rest on."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import HLOCost


def cost_of(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return HLOCost(c.as_text()).summary()


def test_single_matmul_exact():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    s = cost_of(lambda a, b: a @ b, a, b)
    assert s["flops"] == 2 * 128 * 256 * 64
    assert s["bytes"] == (128 * 256 + 256 * 64 + 128 * 64) * 4


def test_scan_multiplies_by_trip_count():
    def scanned(a, bs):
        def body(x, b):
            return x @ b, None
        y, _ = jax.lax.scan(body, a, bs)
        return y

    a = jnp.zeros((128, 256), jnp.float32)
    bs = jnp.zeros((7, 256, 256), jnp.float32)
    s = cost_of(scanned, a, bs)
    assert s["flops"] == 7 * 2 * 128 * 256 * 256


def test_nested_scans_multiply():
    def inner(x, bs):
        def body(x, b):
            return x @ b, None
        y, _ = jax.lax.scan(body, x, bs)
        return y

    def outer(a, bss):
        def body(x, bs):
            return inner(x, bs), None
        y, _ = jax.lax.scan(body, a, bss)
        return y

    a = jnp.zeros((32, 64), jnp.float32)
    bss = jnp.zeros((3, 5, 64, 64), jnp.float32)
    s = cost_of(outer, a, bss)
    assert s["flops"] == 3 * 5 * 2 * 32 * 64 * 64


def test_grad_counts_backward_dots():
    def mlp(w1, w2, x):
        return jnp.sum(jnp.tanh(x @ w1) @ w2)

    w1 = jnp.zeros((64, 128))
    w2 = jnp.zeros((128, 32))
    x = jnp.zeros((16, 64))
    fwd = cost_of(mlp, w1, w2, x)["flops"]
    both = cost_of(jax.grad(mlp, argnums=(0, 1)), w1, w2, x)["flops"]
    # backward adds at least the two weight-gradient dots
    assert both >= fwd + 2 * 128 * 16 * 32 + 2 * 64 * 16 * 128


def test_collectives_ignored_in_bytes_but_tracked():
    # single-device: no collectives expected; field still present
    a = jnp.zeros((8, 8))
    s = cost_of(lambda a: a @ a, a)
    assert s["collective_bytes"] == 0.0
