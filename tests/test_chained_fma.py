"""THE paper property (§III.B): the skewed pipeline's speculative exponent
forwarding + retimed normalization is *exact* — bit-identical results to the
baseline normalize-then-align pipeline, for every chain and format."""
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-stub shim

from repro.core import chained_fma as cf
from repro.core.fpformats import (BF16, FP8_E4M3, FP8_E5M2, FP16, get_format,
                                  quantize_np)


def bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


FMTS = [BF16, FP8_E4M3, FP8_E5M2, FP16]


@pytest.mark.parametrize("fmt", FMTS, ids=lambda f: f.name)
def test_skew_equals_baseline_random(fmt):
    rng = np.random.default_rng(7)
    for scale in (1.0, 17.0, 1e-3):
        a = quantize_np(rng.standard_normal((64, 33)) * scale, fmt)
        w = quantize_np(rng.standard_normal((33, 48)) * scale, fmt)
        b = cf.matmul_emulated(a, w, fmt, "baseline")
        s = cf.matmul_emulated(a, w, fmt, "skewed")
        np.testing.assert_array_equal(bits(b), bits(s))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(
    st.floats(-1e4, 1e4, allow_nan=False, width=32),
    st.floats(-1e4, 1e4, allow_nan=False, width=32)),
    min_size=1, max_size=64))
def test_skew_equals_baseline_hypothesis(pairs):
    a = quantize_np(np.array([p[0] for p in pairs], np.float32), BF16)
    w = quantize_np(np.array([p[1] for p in pairs], np.float32), BF16)
    ac = a.reshape(-1, 1, 1)
    wc = w.reshape(-1, 1, 1)
    b = cf.baseline_chain(ac, wc, BF16)
    s = cf.skewed_chain(ac, wc, BF16)
    np.testing.assert_array_equal(bits(b), bits(s))


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from(["bf16", "fp8_e4m3"]))
def test_skew_equals_baseline_adversarial(seed, fmt_name):
    """Cancellation-heavy chains: alternating signs, wide exponent swings."""
    fmt = get_format(fmt_name)
    rng = np.random.default_rng(seed)
    k = rng.integers(1, 40)
    mags = 2.0 ** rng.integers(-20, 20, size=k)
    a = quantize_np(mags * rng.choice([-1.0, 1.0], k), fmt)
    w = quantize_np(rng.standard_normal(k), fmt)
    # inject exact zeros and repeated-value cancellations
    if k > 4:
        a[1] = 0.0
        a[2], w[2] = a[0], -w[0] if fmt_name == "bf16" else w[2]
    ac, wc = a.reshape(-1, 1), w.reshape(-1, 1)
    b = cf.baseline_chain(ac, wc, fmt)
    s = cf.skewed_chain(ac, wc, fmt)
    np.testing.assert_array_equal(bits(b), bits(s))


def test_chain_matches_float64_within_fp32_error():
    rng = np.random.default_rng(3)
    a = quantize_np(rng.standard_normal((8, 100)), BF16)
    w = quantize_np(rng.standard_normal((100, 8)), BF16)
    got = cf.matmul_emulated(a, w, BF16, "skewed").astype(np.float64)
    ref = a.astype(np.float64) @ w.astype(np.float64)
    # truncating FP32 accumulation: error bounded by ~K ulps of the running sum
    err = np.abs(got - ref)
    bound = 100 * np.spacing(np.abs(ref).max().astype(np.float32)).astype(np.float64)
    assert err.max() <= bound * 4


def test_exact_when_no_alignment_truncation():
    """Products with equal exponents accumulate exactly (no bits dropped)."""
    a = np.full((1, 16), 1.5, np.float32)
    w = np.full((16, 1), 2.0, np.float32)
    out = cf.matmul_emulated(a, w, BF16, "skewed")
    assert out[0, 0] == np.float32(1.5 * 2.0 * 16)


def test_zero_and_sign_edge_cases():
    cases = [
        ([0.0, 0.0, 0.0], [1.0, 2.0, 3.0], 0.0),
        ([1.5, -1.5, 0.0], [1.0, 1.0, 5.0], 0.0),
        # truncating 27-bit accumulator: the 2^-60 term is dropped by
        # alignment before the big terms cancel (matches IEEE fp32 chains)
        ([2.0**-60, 2.0**60, -(2.0**60)], [1.0, 1.0, 1.0], 0.0),
    ]
    for av, wv, want in cases:
        a = np.asarray(av, np.float32).reshape(-1, 1)
        w = np.asarray(wv, np.float32).reshape(-1, 1)
        b = cf.baseline_chain(a, w, BF16)
        s = cf.skewed_chain(a, w, BF16)
        np.testing.assert_array_equal(bits(b), bits(s))
        assert b.reshape(()) == np.float32(want)


def test_speculation_algebra_dspec_correction():
    """d = d' + L  (e_M ≥ ê) and |d| = |L − d'| (e_M < ê): spot-check the
    fix unit against direct exponent arithmetic (paper §III.B equations)."""
    rng = np.random.default_rng(11)
    a = quantize_np(rng.standard_normal((200,)) * 3, BF16)
    w = quantize_np(rng.standard_normal((200,)) * 3, BF16)
    acc = cf.make_zero_unnorm(())
    for k in range(200):
        prod = cf.multiply(np.float32(a[k]), np.float32(w[k]), BF16)
        nxt = cf.skewed_pe(prod, acc)
        if acc.S != 0 and prod.m != 0:
            e_prev = int(acc.ehat - acc.L)            # corrected exponent
            d_true = abs(int(prod.e) - e_prev)
            d_spec = abs(int(prod.e) - int(acc.ehat))
            if prod.e >= acc.ehat:
                assert d_true == d_spec + int(acc.L)   # paper eq., case 1
            else:
                assert d_true == abs(int(acc.L) - d_spec)  # case 2
        acc = nxt
