"""Disaggregated two-pool serving (DESIGN.md §10): the prefill pool stages
KV pages and a ready queue feeds decode admissions. Tokens must be
identical to the unified engine (the handoff runs the same scatter+bind
writes `_insert_impl` fuses), no page may leak through the
prefill→ready→retirement lease, and the two-pool scheduler / replica
router / prompt-length bucketing each keep their contracts."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import PrecisionPolicy, use_policy
from repro.configs import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine, _bucket_len
from repro.serve.scheduler import ReplicaRouter, SlotScheduler

FP32 = PrecisionPolicy(input_format="fp32")


def _cfg(name="qwen2.5-14b", **kw):
    return dataclasses.replace(reduced_config(name, **kw), remat=False)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).tolist() for n in lens]


def _serve(cfg, params, prompts, budgets, arrivals=None, *, batch=2,
           cache_len=64, page_size=8, sync_every=4, **engine_kw):
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=batch, cache_len=cache_len,
                             eos_id=-1, sync_every=sync_every,
                             kv_layout="paged", page_size=page_size,
                             **engine_kw)
        sched = SlotScheduler(batch, eos_id=-1)
        for i, (p, n) in enumerate(zip(prompts, budgets)):
            sched.submit(p, max_new_tokens=n,
                         arrival_time=arrivals[i] if arrivals else 0.0)
        summary = engine.serve(sched, greedy=True)
    return sched, summary


def _tokens_by_rid(sched):
    return {r.rid: r.tokens for r in sched.finished}


def test_disagg_matches_unified(setup):
    """The acceptance gate: the two-pool engine's token streams are
    bit-identical to the unified engine's on a staggered mixed-length
    stream, no pages leak through the handoff, and only the two-pool run
    reports ready-queue depth."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 9, 13, 6, 11, 7])
    budgets = [12, 6, 9, 5, 10, 8]
    arrivals = [0.0, 0.0, 0.1, 0.1, 0.2, 0.2]
    on_sched, on = _serve(cfg, params, prompts, budgets, arrivals,
                          disagg=True, prefill_workers=2)
    off_sched, off = _serve(cfg, params, prompts, budgets, arrivals,
                            disagg=False)
    assert _tokens_by_rid(on_sched) == _tokens_by_rid(off_sched)
    assert len(on_sched.finished) == len(prompts)
    assert on["disagg"] is True and off["disagg"] is False
    assert on["pages_leaked"] == 0 and off["pages_leaked"] == 0
    assert "ready_depth_p50" in on and "ready_depth_p50" not in off
    assert {"prefill_busy_s", "decode_busy_s", "handoff_s",
            "decode_stall_s"} <= set(on) & set(off)


def test_disagg_prefix_cache_parity(setup):
    """Prefix-cache hits survive the two-pool split: the prefill worker
    maps cached pages (COW tail included) before staging, registers the
    fresh run before the request reaches the ready queue, and tokens stay
    identical to unified with the same hit pattern."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    system = rng.integers(0, cfg.vocab_size, 16).tolist()
    tails = _prompts(cfg, [5, 7, 9], seed=10)
    prompts = [system + t for t in tails]
    budgets = [6, 6, 6]
    on_sched, on = _serve(cfg, params, prompts, budgets,
                          disagg=True, cache_len=96)
    off_sched, off = _serve(cfg, params, prompts, budgets,
                            disagg=False, cache_len=96)
    assert _tokens_by_rid(on_sched) == _tokens_by_rid(off_sched)
    assert on["prefix_hits"] >= 1
    assert on["prefix_hits"] == off["prefix_hits"]
    assert on["pages_leaked"] == 0


def test_disagg_first_token_finishes_at_prefill(setup):
    """A max_new=1 request retires inside finish_prefill — it never enters
    the ready queue or a decode slot — while its neighbours decode
    normally; the leased pages still come home."""
    cfg, params = setup
    prompts = _prompts(cfg, [6, 8, 7], seed=4)
    budgets = [1, 8, 1]
    sched, summary = _serve(cfg, params, prompts, budgets, disagg=True)
    by_rid = {r.rid: r for r in sched.finished}
    assert len(by_rid) == 3
    for rid in (0, 2):
        assert by_rid[rid].n_generated == 1
        assert by_rid[rid].slot == -1          # never bound to a slot
        assert by_rid[rid].finish_reason == "length"
    assert by_rid[1].n_generated == 8
    assert summary["pages_leaked"] == 0


def test_two_pool_scheduler_unit():
    """begin_prefill / finish_prefill / admit_ready semantics without an
    engine: arrival gating, ready staging, slot binding, the reject path,
    and drained() counting staged-but-unbound work."""
    sched = SlotScheduler(2, eos_id=-1)
    r0 = sched.submit([1, 2, 3], max_new_tokens=4, arrival_time=0.0)
    r1 = sched.submit([4, 5], max_new_tokens=3, arrival_time=5.0)
    r2 = sched.submit([6, 7], max_new_tokens=1, arrival_time=5.0)

    assert sched.begin_prefill(0.0) is r0
    assert sched.begin_prefill(0.0) is None      # r1 hasn't arrived yet
    assert sched.ready_depth() == 0
    assert sched.finish_prefill(r0, 42, 0.1) is True
    assert sched.ready_depth() == 1 and not sched.drained()
    assert r0.tokens == [42] and r0.t_first_token is not None

    got = sched.admit_ready(0, 0.2)
    assert got is r0 and r0.slot == 0
    assert sched.ready_depth() == 0 and sched.num_active() == 1
    assert sched.admit_ready(1, 0.2) is None     # nothing staged

    # arrival-sorted FIFO: ties at t=5.0 pop in submit order (r1 before r2)
    assert sched.begin_prefill(6.0) is r1
    assert sched.begin_prefill(6.0) is r2

    # a single-token budget retires inside finish_prefill: never queued
    sched2 = SlotScheduler(2, eos_id=-1)
    short = sched2.submit([6, 7], max_new_tokens=1, arrival_time=0.0)
    assert sched2.begin_prefill(0.0) is short
    assert sched2.finish_prefill(short, 9, 0.1) is False
    assert short.finish_reason == "length" and short.t_done is not None
    assert sched2.ready_depth() == 0 and sched2.drained()

    sched3 = SlotScheduler(2, eos_id=-1)
    doomed = sched3.submit([1] * 8, max_new_tokens=4, arrival_time=0.0)
    assert sched3.begin_prefill(0.0) is doomed
    sched3.reject_prefill(doomed, 0.0)
    assert doomed.finish_reason == "rejected" and doomed.t_done is not None
    assert sched3.drained() and doomed in sched3.finished

    # summary reports ready-depth percentiles only once two-pool mode ran
    assert "ready_depth_p50" in sched.summary()
    assert "ready_depth_p50" not in SlotScheduler(2, eos_id=-1).summary()


def test_replica_router():
    """Pick-least-loaded by outstanding token estimate, ties to the lowest
    index — a pure function of the routed stream."""
    with pytest.raises(ValueError):
        ReplicaRouter(0)
    r = ReplicaRouter(3)
    assert r.route(4, 4) == 0          # all empty: lowest index
    assert r.route(2, 2) == 1
    assert r.route(1, 1) == 2
    assert r.route(1, 1) == 2          # replica 2 lightest (2 < 8, 4)
    assert r.outstanding == [8, 4, 4]
    r.complete(0, 4, 4)
    assert r.outstanding == [0, 4, 4]
    assert r.route(1, 1) == 0
    assert r.routed == [2, 1, 2]
    with pytest.raises(AssertionError):
        r.complete(1, 100, 100)        # over-completion is a bug


def test_bucket_len_sequence():
    """Buckets step 8 → 12 → 16 → 24 → 32 → 48 → 64 → 96: alternating
    x1.5 / x1.33, so padding waste stays under 50% at every length."""
    got = [_bucket_len(n)
           for n in (1, 8, 9, 12, 13, 16, 17, 24, 25, 32, 33, 48, 49, 65)]
    assert got == [8, 8, 12, 12, 16, 16, 24, 24, 32, 32, 48, 48, 64, 96]
    for n in range(1, 200):
        b = _bucket_len(n)
        assert b >= n and b < 2 * max(n, 8)


def test_bucketed_serve_identical_fewer_compiles(setup):
    """Prompt-length bucketing pads prefill to the bucket grid: token
    streams stay bit-identical (padded rows carry position -1, the last
    real row feeds the lm head) while distinct prefill traces drop."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 6, 7, 9], seed=6)
    budgets = [6, 6, 6, 6]
    on_sched, on = _serve(cfg, params, prompts, budgets,
                          bucket_prompts=True)
    off_sched, off = _serve(cfg, params, prompts, budgets,
                            bucket_prompts=False)
    assert _tokens_by_rid(on_sched) == _tokens_by_rid(off_sched)
    assert on["prefill_compiles"] < off["prefill_compiles"]


def test_disagg_composes_with_bucketing(setup):
    """Both knobs on at once still reproduce the plain engine's streams —
    the staged fragment is bucket-padded, overflow pages land in the trash
    page, and the handoff binds only the allocated run."""
    cfg, params = setup
    prompts = _prompts(cfg, [5, 9, 13, 7], seed=8)
    budgets = [8, 6, 7, 5]
    on_sched, on = _serve(cfg, params, prompts, budgets,
                          disagg=True, bucket_prompts=True)
    off_sched, off = _serve(cfg, params, prompts, budgets)
    assert _tokens_by_rid(on_sched) == _tokens_by_rid(off_sched)
    assert on["pages_leaked"] == 0
