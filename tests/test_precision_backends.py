"""Backend parity for the production GEMM path: the pallas SA kernel must be
a drop-in for the xla backend — values AND gradients — including the fused
epilogue (bias/act/scale before the single rounding) and the autotune cache
that picks its block shapes."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.precision import PrecisionPolicy, sa_dot, use_policy
from repro.kernels import autotune as at
from repro.kernels import ops
from repro.kernels.sa_matmul import apply_act

RNG = np.random.default_rng(7)

RAGGED = [(33, 257, 65), (100, 96, 50), (1, 256, 3), (64, 64, 64)]


def _abc(m, k, n):
    a = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((n,)), jnp.float32)
    return a, w, b


# ---------------------------------------------------------------------------
# sa_dot: pallas ≡ xla (values and grads) across formats and ragged shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["bf16", "fp8_e4m3", "fp8_e5m2"])
@pytest.mark.parametrize("m,k,n", RAGGED)
def test_backend_value_parity(fmt, m, k, n):
    a, w, _ = _abc(m, k, n)
    yx = sa_dot(a, w, PrecisionPolicy(input_format=fmt, backend="xla"))
    yp = sa_dot(a, w, PrecisionPolicy(input_format=fmt, backend="pallas"))
    assert yp.shape == (m, n) and yp.dtype == yx.dtype
    scale = float(jnp.max(jnp.abs(yx))) + 1e-6
    assert float(jnp.max(jnp.abs(yx - yp))) / scale < 2e-6


@pytest.mark.parametrize("fmt", ["bf16", "fp8_e4m3"])
def test_backend_grad_parity(fmt):
    a, w, _ = _abc(33, 64, 17)

    def loss(backend):
        pol = PrecisionPolicy(input_format=fmt, backend=backend)
        return lambda a, w: (sa_dot(a, w, pol) ** 2).sum()

    gx = jax.grad(loss("xla"), argnums=(0, 1))(a, w)
    gp = jax.grad(loss("pallas"), argnums=(0, 1))(a, w)
    for x, p in zip(gx, gp):
        scale = float(jnp.max(jnp.abs(x))) + 1e-6
        # bf16 tolerance: the two backends round once at the same place but
        # may order the fp32 reduction differently
        assert float(jnp.max(jnp.abs(x - p))) / scale < 1e-2


# ---------------------------------------------------------------------------
# fused epilogue: in-kernel act/bias/scale ≡ unfused reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["relu", "gelu", "silu"])
def test_epilogue_fusion_matches_unfused(act):
    a, w, b = _abc(33, 96, 40)
    y = ops.sa_matmul(a, w, bias=b, act=act, bm=32, bn=32, bk=64)
    y_ref = apply_act(jnp.matmul(a, w, preferred_element_type=jnp.float32)
                      + b, act)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4


def test_epilogue_scale_is_prerounding_descale():
    """FP8 path: the descale rides the epilogue, before the single rounding."""
    a, w, _ = _abc(32, 48, 16)
    s = jnp.float32(0.37)
    y = ops.sa_matmul(a, w, scale=s, bm=32, bn=16, bk=48)
    y_ref = jnp.matmul(a, w, preferred_element_type=jnp.float32) * s
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-5


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_epilogue_grad_parity(act):
    a, w, b = _abc(24, 48, 20)
    px = PrecisionPolicy(backend="xla")
    pp = PrecisionPolicy(backend="pallas")

    def f(pol):
        return lambda a, w, b: sa_dot(a, w, pol, bias=b, act=act).sum()

    gx = jax.grad(f(px), argnums=(0, 1, 2))(a, w, b)
    gp = jax.grad(f(pp), argnums=(0, 1, 2))(a, w, b)
    for x, p in zip(gx, gp):
        np.testing.assert_allclose(np.asarray(x), np.asarray(p),
                                   rtol=1e-4, atol=1e-5)


def test_sa_dot_epilogue_all_backends_agree():
    a, w, b = _abc(16, 32, 8)
    ys = [sa_dot(a, w, PrecisionPolicy(backend=bk), bias=b, act="relu")
          for bk in ("xla", "pallas", "emulate")]
    for y in ys[1:]:
        np.testing.assert_allclose(np.asarray(ys[0]), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------

@pytest.fixture
def tuned_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    # ambient REPRO_AUTOTUNE=1 would make lookup() sweep on miss and break
    # the never-tunes assertions below
    monkeypatch.delenv("REPRO_AUTOTUNE", raising=False)
    at.reset()
    yield path
    at.reset()   # don't leak tmp-path entries into other tests' lookups


def test_autotune_roundtrip_and_memo(tuned_cache):
    best, table = at.tune(48, 32, 64, dtype="float32", reps=1)
    assert best == tuple(table[0]["blocks"])
    assert all(table[i]["us"] <= table[i + 1]["us"]
               for i in range(len(table) - 1))
    # in-process hit
    assert at.lookup(48, 32, 64, dtype="float32") == best
    # on-disk hit after a simulated process restart
    at.reset()
    assert at.lookup(48, 32, 64, dtype="float32") == best
    data = json.load(open(tuned_cache))
    assert data["version"] == 1
    key, = data["entries"]
    assert key.startswith(at.backend_key()) and "48x32x64" in key


def test_autotune_corrupt_cache_not_fatal(tuned_cache):
    with open(tuned_cache, "w") as f:
        f.write("{definitely not json")
    at.reset()
    blocks = at.lookup(48, 32, 64, dtype="float32")   # must not raise
    assert blocks == at.default_blocks(48, 32, 64)
    # tuning over a corrupt file replaces it with a valid one
    best, _ = at.tune(48, 32, 64, dtype="float32", reps=1)
    assert json.load(open(tuned_cache))["entries"]


def test_autotune_miss_uses_heuristic_without_sweeping(tuned_cache):
    assert at.lookup(8, 8, 8, dtype="float32") == at.default_blocks(8, 8, 8)
    assert not os.path.exists(tuned_cache)   # lookup alone never tunes


def test_autotuned_blocks_feed_sa_matmul(tuned_cache):
    a, w, _ = _abc(48, 64, 32)
    at.tune(48, 32, 64, dtype="float32", reps=1)
    y = ops.sa_matmul(a, w)    # block dims resolved via the cache
    y_ref = jnp.matmul(a, w, preferred_element_type=jnp.float32)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-5


# ---------------------------------------------------------------------------
# the headline scenario: training on the pallas backend
# ---------------------------------------------------------------------------

def test_train_step_pallas_matches_xla():
    """One full train step (model fwd, jax.grad, AdamW) per backend."""
    from repro.configs import reduced_config
    from repro.train.optimizer import AdamW, constant_lr
    from repro.train.step import make_train_step
    from repro.train.train_state import init_state

    cfg = reduced_config("gemma2-9b")
    opt = AdamW(schedule=constant_lr(1e-3))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    results = {}
    for backend in ("xla", "pallas"):
        step = make_train_step(cfg, opt)
        with use_policy(PrecisionPolicy(backend=backend)):
            state = init_state(jax.random.key(0), cfg, opt)
            # fresh lambda per backend: the policy is trace-time state, so a
            # shared jit cache entry would silently reuse the other backend
            state1, metrics = jax.jit(lambda s, b: step(s, b))(state, batch)
        results[backend] = (state1, {k: float(v) for k, v in metrics.items()})

    lx = results["xla"][1]["loss"]
    lp = results["pallas"][1]["loss"]
    assert np.isfinite(lp)
    assert abs(lx - lp) <= 1e-2 * max(1.0, abs(lx))   # bf16-level tolerance
    for px, pp in zip(jax.tree.leaves(results["xla"][0].params),
                      jax.tree.leaves(results["pallas"][0].params)):
        np.testing.assert_allclose(np.asarray(px, np.float32),
                                   np.asarray(pp, np.float32),
                                   rtol=1e-2, atol=1e-3)
