"""Cycle model of the SA (paper §II–IV): latency algebra + headline claims.

The closed-form `tile_latency`/`gemm_latency` algebra is cross-checked
against a brute-force per-PE event simulation (bottom of this file): every
MAC is scheduled individually from its dependencies, so an off-by-one in the
algebra cannot hide behind another formula."""
import itertools
import math

import pytest

from repro.core import energy as E
from repro.core import workloads as wl
from repro.core.systolic import (BASELINE, CYCLES_PER_ROW, EXTRA_STAGES,
                                 SKEWED, SAConfig, gemm_latency, speedup,
                                 tile_latency, utilization)


def test_tile_latency_formulas():
    # baseline: 2 cycles per row of the reduction chain (Fig. 4)
    assert (tile_latency(M=1, r_used=128, c_used=1, pipeline=BASELINE)
            == 2 * 128 + 0 + 1 + 1)
    # skewed: 1 cycle per row + extra trailing add stage (Fig. 6)
    assert (tile_latency(M=1, r_used=128, c_used=1, pipeline=SKEWED)
            == 128 + 0 + 1 + 2)


def test_skew_saves_r_cycles_per_tile():
    for r in (1, 16, 128):
        d = tile_latency(10, r, 8, BASELINE) - tile_latency(10, r, 8, SKEWED)
        assert d == r - 1    # 2R − R minus the extra add stage


def test_latency_monotone_in_everything():
    sa = SAConfig(pipeline=BASELINE)
    base = gemm_latency(64, 256, 256, sa)
    assert gemm_latency(128, 256, 256, sa) > base
    assert gemm_latency(64, 512, 256, sa) > base
    assert gemm_latency(64, 256, 512, sa) > base


def test_streaming_bound_large_M():
    """For M ≫ fill, both pipelines converge to ~M cycles/tile (speedup→1)."""
    assert speedup(100_000, 128, 128) == pytest.approx(1.0, abs=0.01)
    # latency-bound regime: small M ⇒ fill dominates; with the exposed
    # initial weight load + column stagger the model gives ~1.33
    assert speedup(1, 128, 128) > 1.3


def test_utilization_bounds():
    sa = SAConfig()
    u = utilization(4096, 128, 128, sa)
    assert 0.9 < u <= 1.0
    assert utilization(1, 1, 1, sa) < 0.01


def test_gemm_tiling_counts():
    sa = SAConfig(rows=128, cols=128, pipeline=BASELINE)
    one = gemm_latency(16, 128, 128, sa)
    four = gemm_latency(16, 256, 256, sa)
    # 4 tiles ≈ 4× one-tile compute (+ the shared initial weight load)
    assert abs(four - (4 * (one - 128) + 128)) <= 1


# ----------------------------------------------------------------------
# Paper §IV headline claims (tolerances documented in EXPERIMENTS.md)
# ----------------------------------------------------------------------

def test_paper_headline_mobilenet():
    t = E.network_totals("mobilenet")
    assert abs(t["latency_saving"] - 0.16) < 0.04   # paper: 16 %
    assert abs(t["energy_saving"] - 0.08) < 0.04    # paper: 8 %


def test_paper_headline_resnet50():
    t = E.network_totals("resnet50")
    assert abs(t["latency_saving"] - 0.21) < 0.04   # paper: 21 %
    assert abs(t["energy_saving"] - 0.11) < 0.04    # paper: 11 %


def test_paper_area_power_constants():
    assert E.REL_AREA[SKEWED] == 1.09               # paper: +9 % area
    assert E.REL_POWER[SKEWED] == 1.07              # paper: +7 % power
    skew = SAConfig(pipeline=SKEWED)
    base = SAConfig(pipeline=BASELINE)
    assert (E.array_area_mm2(skew) / E.array_area_mm2(base)
            == pytest.approx(1.09))


def test_per_layer_energy_crossover():
    """Figs. 7/8: early layers (huge M) lose energy, late layers win big."""
    reps = E.network_report("mobilenet")
    pw = [r for r in reps if r.layer.startswith("pw")]
    assert pw[0].energy_saving < 0.02               # early: ≈ no win / loss
    assert pw[-1].energy_saving > 0.15              # late: big win
    assert pw[-1].latency_saving > 0.25


# ----------------------------------------------------------------------
# Brute-force cycle simulation vs the closed-form latency algebra
# ----------------------------------------------------------------------

def _simulate_tile(M: int, r_used: int, c_used: int, pipeline: str) -> int:
    """Schedule every MAC of one resident weight tile individually.

    Dependencies per PE (row rr, col cc) working on input row m:
      * west input: the operand reaches column cc at cycle m + cc (one-cycle
        west→east skew),
      * the chain: the partial sum from PE rr−1 arrives CYCLES_PER_ROW after
        that PE issued (2 for baseline — Fig. 4; 1 for skewed — Fig. 6),
      * occupancy: a PE issues at most one MAC per cycle (II = 1).
    The last result then drains the final PE's own pipeline plus the
    column-end trailing stages (extra add for skewed, rounder for both).
    Returns the total cycle count.
    """
    cpr = CYCLES_PER_ROW[pipeline]
    done = 0
    for cc in range(c_used):
        prev_row_issue = [-10**9] * r_used     # last issue cycle per PE
        for m in range(M):
            t = m + cc                         # west input arrival
            for rr in range(r_used):
                t = max(t, prev_row_issue[rr] + 1)   # occupancy
                prev_row_issue[rr] = t
                t += cpr                       # chain hop to PE rr+1
            # t is now when the column-end logic receives the partial sum;
            # it spends EXTRA_STAGES cycles there, writing out in the last —
            # so the cycle *count* is that final index + 1
            finish = t + EXTRA_STAGES[pipeline]
            done = max(done, finish + 1)
    return done


def _simulate_gemm(M: int, K: int, N: int, sa: SAConfig) -> int:
    """Tile-by-tile timeline with explicit double-buffered weight loads.

    Unlike `gemm_latency`, nothing assumes loads are hidden: the next tile's
    load (r_used cycles through the north ports) starts with the current
    tile's compute, and the next compute waits on max(compute_end, load_end).
    """
    if min(M, K, N) <= 0:
        return 0
    tiles = []
    for ki in range(math.ceil(K / sa.rows)):
        r_used = min(sa.rows, K - ki * sa.rows)
        for ni in range(math.ceil(N / sa.cols)):
            c_used = min(sa.cols, N - ni * sa.cols)
            tiles.append((r_used, c_used))
    t = tiles[0][0]                            # exposed initial weight load
    for i, (r_used, c_used) in enumerate(tiles):
        start = t
        end = start + _simulate_tile(M, r_used, c_used, sa.pipeline)
        if i + 1 < len(tiles):
            load_end = start + tiles[i + 1][0]
            end = max(end, load_end)
        t = end
    return t


@pytest.mark.parametrize("pipeline", [BASELINE, SKEWED])
def test_tile_latency_matches_cycle_simulation(pipeline):
    for M, r, c in itertools.product((1, 2, 4, 9), (1, 2, 5, 8), (1, 3, 8)):
        assert (tile_latency(M, r, c, pipeline)
                == _simulate_tile(M, r, c, pipeline)), (M, r, c, pipeline)


@pytest.mark.parametrize("pipeline", [BASELINE, SKEWED])
def test_gemm_latency_matches_cycle_simulation(pipeline):
    """Small arrays, K/N not multiples of rows/cols ⇒ partial tiles
    (r_used < rows) on the last K and N tile are exercised."""
    sa = SAConfig(rows=8, cols=8, pipeline=pipeline)
    for M, K, N in itertools.product((1, 5, 17), (3, 8, 20), (1, 6, 16)):
        assert gemm_latency(M, K, N, sa) == _simulate_gemm(M, K, N, sa), (
            M, K, N, pipeline)


@pytest.mark.parametrize("pipeline", [BASELINE, SKEWED])
def test_partial_tile_edge(pipeline):
    """r_used < rows: the fill shortens with the chain actually present."""
    full = tile_latency(4, 8, 8, pipeline)
    part = tile_latency(4, 3, 8, pipeline)
    assert part == _simulate_tile(4, 3, 8, pipeline)
    assert full - part == CYCLES_PER_ROW[pipeline] * 5


def test_workload_shapes():
    mb = wl.mobilenet_v1()
    rn = wl.resnet50()
    assert len(mb) == 1 + 13 * 2 + 1
    assert len(rn) == 1 + (3 + 4 + 6 + 3) * 3 + 4 + 1
    macs = sum(wl.layer_macs(l) for l in mb)
    assert 0.5e9 < macs < 0.64e9     # MobileNetV1 ≈ 0.57 GMACs
    macs_rn = sum(wl.layer_macs(l) for l in rn)
    assert 3.5e9 < macs_rn < 4.3e9   # ResNet50 ≈ 3.8–4.1 GMACs
