"""Shared test fixtures/shims.

`given`/`settings`/`st` resolve to real hypothesis when installed; otherwise
to stubs that skip only the property tests, so the deterministic tests in
the same modules keep running. Import in test modules as
``from conftest import given, settings, st``.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from unittest import mock

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed "
                                "(pip install -r requirements-dev.txt)")
    settings = given
    st = mock.MagicMock()
