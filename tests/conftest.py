"""Shared test fixtures/shims.

`given`/`settings`/`st` resolve to real hypothesis when installed (CI
installs requirements-dev.txt). When it isn't, a deterministic mini
property-runner stands in: same decorator surface, a seeded example
generator biased toward floating-point edge cases (signed zeros, powers of
two across the exponent range, format boundaries, random bit patterns), and
a falsifying-example report on failure. No shrinking, no example database —
but the property tests *run* instead of skipping. Import in test modules as
``from conftest import given, settings, st``.
"""
import functools
import inspect
import zlib

import numpy as np

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw callable: rng → example value."""

        def __init__(self, draw):
            self.draw = draw

    def _bits_to_f32(bits):
        return float(np.asarray(np.uint32(bits)).view(np.float32))

    class _St:
        """The subset of hypothesis.strategies this repo's tests use."""

        @staticmethod
        def floats(min_value=None, max_value=None, *, allow_nan=False,
                   allow_infinity=False, width=64):
            lo = -3.4e38 if min_value is None else float(min_value)
            hi = 3.4e38 if max_value is None else float(max_value)
            specials = [0.0, -0.0, 1.0, -1.0, 1.5, -1.5, lo, hi]
            specials += [s * 2.0 ** e
                         for e in (-126, -60, -24, -6, -1, 1, 6, 24, 60, 127)
                         for s in (1.0, -1.0)]
            specials = [s for s in specials
                        if np.isfinite(s) and lo <= s <= hi]

            def draw(rng):
                r = rng.random()
                if r < 0.25 and specials:
                    v = specials[int(rng.integers(len(specials)))]
                elif r < 0.5:
                    # random bit pattern: sweeps the whole exponent range
                    # (uniform draws almost never produce tiny magnitudes)
                    v = _bits_to_f32(rng.integers(0, 2 ** 32))
                    if not np.isfinite(v) or not lo <= v <= hi:
                        v = float(rng.uniform(lo, hi))
                else:
                    v = float(rng.uniform(lo, hi))
                return float(np.float32(v)) if width == 32 else v

            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value):
            def draw(rng):
                if rng.random() < 0.1:
                    return int(min_value if rng.random() < 0.5 else max_value)
                return int(rng.integers(int(min_value), int(max_value) + 1))

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.integers(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]

            return _Strategy(draw)

        @staticmethod
        def tuples(*strategies):
            def draw(rng):
                return tuple(s.draw(rng) for s in strategies)

            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)

            def draw(rng):
                return seq[int(rng.integers(len(seq)))]

            return _Strategy(draw)

    st = _St()

    def settings(max_examples=100, deadline=None, **_):
        # applied *above* @given in every use here, so it annotates the
        # given-wrapper; the wrapper reads the attribute at call time
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", 100)
                seed0 = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = np.random.default_rng((seed0, i))
                    drawn = [s.draw(rng) for s in strategies]
                    try:
                        fn(*drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example #{i} "
                            f"(seed ({seed0}, {i})): {drawn!r}") from e

            # pytest reads the signature to resolve fixtures: the drawn
            # params must not look like fixture requests
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper._max_examples = 100
            return wrapper

        return deco
