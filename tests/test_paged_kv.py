"""Paged KV cache: paged ≡ ring parity, pool-gated admission, page
accounting. The paged layout (DESIGN.md §5) replaces per-slot fixed rings
with a global page pool + per-slot block tables; these tests pin the two
layouts to identical tokens and the allocator to leak-free bookkeeping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PrecisionPolicy, use_policy
from repro.configs import reduced_config
from repro.models import model as M
from repro.models.layers import PagedKVCache, gather_pages
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import PageAllocator, SlotScheduler

FP32 = PrecisionPolicy(input_format="fp32")


def _cfg(name="qwen2.5-14b"):
    return dataclasses.replace(reduced_config(name), remat=False)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).tolist() for n in lens]


def _reference_decode(cfg, params, prompt, n, cache_len=64):
    prompt_a = jnp.asarray(prompt, jnp.int32)[None]
    plen = prompt_a.shape[1]
    cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
    logits, cache, _ = M.forward(params, cfg, prompt_a, cache=cache,
                                 last_only=True)
    tok = int(np.asarray(jnp.argmax(logits[0, -1])))
    out = [tok]
    for i in range(n - 1):
        logits, cache, _ = M.forward(
            params, cfg, jnp.asarray([[tok]], jnp.int32), cache=cache,
            pos=jnp.full((1,), plen + i, jnp.int32))
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))
        out.append(tok)
    return out


def _serve(cfg, params, layout, prompts, budgets, eos_id=-1, arrivals=None,
           clock=None, **engine_kw):
    engine = ServeEngine(cfg, params, batch=2, cache_len=64, eos_id=eos_id,
                         sync_every=2, kv_layout=layout, **engine_kw)
    sched = SlotScheduler(2, eos_id=eos_id)
    for i, (p, n) in enumerate(zip(prompts, budgets)):
        t = arrivals[i] if arrivals else 0.0
        sched.submit(p, max_new_tokens=n, arrival_time=t)
    kw = {"clock": clock} if clock else {}
    summary = engine.serve(sched, **kw)
    return sched, summary


def _pool_leaf(cache) -> PagedKVCache:
    for leaf in jax.tree.leaves(
            cache, is_leaf=lambda x: isinstance(x, PagedKVCache)):
        if isinstance(leaf, PagedKVCache):
            return leaf
    raise AssertionError("no paged leaf in cache")


# ---------------------------------------------------------------------------
# parity: paged ≡ ring token-for-token
# ---------------------------------------------------------------------------

def test_paged_matches_ring_under_slot_refill(dense_setup):
    """Four requests through two slots — refills mid-stream — must produce
    identical tokens under both KV layouts (and both must match their
    batch-1 references)."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [5, 9, 7, 11])
    budgets = [20, 4, 6, 5]
    with use_policy(FP32):
        ring, _ = _serve(cfg, params, "ring", prompts, budgets)
        paged, ps = _serve(cfg, params, "paged", prompts, budgets,
                           page_size=16)
        refs = [_reference_decode(cfg, params, p, n)
                for p, n in zip(prompts, budgets)]
    ring_by = {r.rid: r for r in ring.finished}
    paged_by = {r.rid: r for r in paged.finished}
    assert len(paged_by) == 4
    for rid, ref in enumerate(refs):
        assert paged_by[rid].tokens == ring_by[rid].tokens == ref, rid
        assert paged_by[rid].finish_reason == ring_by[rid].finish_reason
    assert ps["slot_refills"] >= 2 and ps["pages_leaked"] == 0


def test_paged_matches_ring_eos_mid_batch(dense_setup):
    """EOS fires in one slot mid-chunk; both layouts must truncate at the
    same token and keep the neighbour slot's stream intact."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [6, 8], seed=3)
    with use_policy(FP32):
        probe = _reference_decode(cfg, params, prompts[1], 10)
        eos = probe[2]
        ring, _ = _serve(cfg, params, "ring", prompts, [12, 12], eos_id=eos)
        paged, _ = _serve(cfg, params, "paged", prompts, [12, 12],
                          eos_id=eos, page_size=16)
    for rid in (0, 1):
        ring_r = next(x for x in ring.finished if x.rid == rid)
        paged_r = next(x for x in paged.finished if x.rid == rid)
        assert paged_r.tokens == ring_r.tokens
        assert paged_r.finish_reason == ring_r.finish_reason
    eos_r = next(x for x in paged.finished if x.rid == 1)
    assert eos_r.finish_reason == "eos" and eos_r.tokens[-1] == eos
    assert eos_r.n_generated == 3


def test_paged_matches_ring_staggered_arrivals(dense_setup):
    """Poisson-style staggered arrivals under a frozen clock: the engine's
    fast-forward admission order must be layout-independent."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [6, 6, 8], seed=11)
    arrivals = [5.0, 9.0, 9.5]
    with use_policy(FP32):
        ring, _ = _serve(cfg, params, "ring", prompts, [3, 3, 4],
                         arrivals=arrivals, clock=lambda: 0.0)
        paged, _ = _serve(cfg, params, "paged", prompts, [3, 3, 4],
                          arrivals=arrivals, clock=lambda: 0.0,
                          page_size=16)
    assert ({r.rid: r.tokens for r in paged.finished}
            == {r.rid: r.tokens for r in ring.finished})
    assert all(r.ttft == 0.0 for r in paged.finished)


def _arch_parity(arch, page_size=8, cache_len=32):
    """Ring vs paged token parity for one arch (three requests, refill)."""
    cfg = _cfg(arch)
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
        prompts = _prompts(cfg, [6, 10, 7], seed=2)
        budgets = [8, 3, 5]

        def run(layout):
            eng = ServeEngine(cfg, params, batch=2, cache_len=cache_len,
                              eos_id=-1, sync_every=2, kv_layout=layout,
                              page_size=page_size)
            sched = SlotScheduler(2, eos_id=-1)
            for p, n in zip(prompts, budgets):
                sched.submit(p, max_new_tokens=n)
            eng.serve(sched)
            return {r.rid: r.tokens for r in sched.finished}

        ring, paged = run("ring"), run("paged")
    assert ring == paged, arch


def test_paged_matches_ring_local_window_arch():
    """gemma3: sliding-window layers keep dense rings inside the paged
    layout and the prefill fragment is floored at `window` — the mixed
    paged-pool/dense-ring splice must still match the ring engine
    token-for-token."""
    cfg = _cfg("gemma3-12b")
    assert any(p == "local" for p in cfg.attn_pattern) and cfg.window
    _arch_parity("gemma3-12b")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["hymba-1.5b", "granite-moe-3b-a800m",
                                  "mamba2-2.7b"])
def test_paged_matches_ring_other_archs(arch):
    """Hybrid (attn∥SSM state splice), MoE (dropless serve dispatch), and
    pure-SSM (paged degrades to ring: nothing to page) all hold parity."""
    _arch_parity(arch)


# ---------------------------------------------------------------------------
# capacity: pooled pages beat per-slot rings
# ---------------------------------------------------------------------------

def test_paged_admits_prompt_beyond_ring_cache_len(dense_setup):
    """A 20-token prompt (+4 budget) overflows the old per-slot ring of 16
    and is rejected there; the paged engine admits it against the shared
    pool — whose total memory stays below the dense allocation a ring
    engine would need to serve the same request — and reproduces the
    batch-1 reference decode exactly."""
    cfg, params = dense_setup
    long_p, short_p = _prompts(cfg, [20, 6], seed=13)
    with use_policy(FP32):
        # ring, cache_len=16: the long request cannot be served
        eng_r = ServeEngine(cfg, params, batch=2, cache_len=16, eos_id=-1,
                            sync_every=2, kv_layout="ring")
        s_r = SlotScheduler(2, eos_id=-1)
        bad = s_r.submit(long_p, max_new_tokens=4)
        s_r.submit(short_p, max_new_tokens=2)
        eng_r.serve(s_r)
        assert bad.finish_reason == "rejected" and bad.tokens == []

        # paged, same cache_len: per-request cap raised to 32 via the block
        # table, pool = 5 pages × 8 = 40 token slots (incl. trash page)
        eng_p = ServeEngine(cfg, params, batch=2, cache_len=16, eos_id=-1,
                            sync_every=2, kv_layout="paged", page_size=8,
                            pool_pages=5, max_seq_len=32)
        s_p = SlotScheduler(2, eos_id=-1)
        r0 = s_p.submit(long_p, max_new_tokens=4)
        r1 = s_p.submit(short_p, max_new_tokens=2)
        summary = eng_p.serve(s_p)
        ref = _reference_decode(cfg, params, long_p, 4, cache_len=32)
    assert r0.finish_reason == "length" and r0.tokens == ref
    assert r1.finish_reason == "length" and len(r1.tokens) == 2
    # total pool memory < the dense ring allocation that could have served
    # the 24-token request: 2 slots × 24 = 48 KV entries per layer
    pool = _pool_leaf(eng_p.new_pool())
    assert pool.k.shape[1] * pool.k.shape[2] == 40 < 2 * 24
    assert summary["pages_leaked"] == 0


def test_admission_blocked_on_pool_exhaustion_then_unblocked():
    """Free slot + exhausted pool ⇒ the head request waits; a retirement
    frees pages and the same request admits. Pure host-side."""
    pa = PageAllocator(4, page_size=8, max_request_pages=3)   # 3 usable
    sched = SlotScheduler(2, eos_id=99, pages=pa)
    r0 = sched.submit([1] * 10, max_new_tokens=6)   # 16 tokens → 2 pages
    r1 = sched.submit([2] * 10, max_new_tokens=6)   # 2 pages
    assert sched.admit(0, now=0.0) is r0 and len(r0.pages) == 2
    assert pa.free_pages == 1
    # slot 1 is free, but r1's 2 pages aren't: admission defers
    assert sched.admit(1, now=0.0) is None
    assert sched.page_blocks == 1 and sched.pending[0] is r1
    # r0 retires (EOS on its first token) → pages return → r1 admits
    sched.start(0, first_token=99, now=0.1)
    assert r0.finish_reason == "eos" and pa.free_pages == 3
    assert sched.drain_freed() == [0]
    assert sched.admit(1, now=0.2) is r1 and len(r1.pages) == 2
    assert pa.free_pages == 1


def test_oversized_request_rejected_paged(dense_setup):
    """More pages than the block table (or pool) can ever hold ⇒ admitted
    with pages=None and retired as rejected; the batch keeps serving."""
    cfg, params = dense_setup
    big, ok = _prompts(cfg, [30, 6], seed=17)
    with use_policy(FP32):
        eng = ServeEngine(cfg, params, batch=2, cache_len=16, eos_id=-1,
                          sync_every=2, kv_layout="paged", page_size=8,
                          pool_pages=5, max_seq_len=16)   # cap: 2 pages/req
        sched = SlotScheduler(2, eos_id=-1)
        bad = sched.submit(big, max_new_tokens=8)     # 38 tokens: never fits
        good = sched.submit(ok, max_new_tokens=4)     # 10 tokens: 2 pages
        summary = eng.serve(sched)
        ref = _reference_decode(cfg, params, ok, 4, cache_len=16)
    assert bad.finish_reason == "rejected" and bad.tokens == []
    assert bad.pages is None
    assert good.tokens == ref
    assert summary["rejected"] == 1 and summary["pages_leaked"] == 0


def test_page_accounting_never_leaks_across_refills(dense_setup):
    """Many requests churn through few slots on a tight pool; every page
    must be accounted for when the stream drains — either back on the free
    list or parked in the prefix cache (refcount 0, retained for reuse).
    Cached-but-unleased pages are NOT leaks: the three-way split
    `pages_leased`/`pages_cached`/`pages_leaked` keeps the leak gate at 0."""
    cfg, params = dense_setup
    n_req = 8
    prompts = _prompts(cfg, [5 + (i % 4) for i in range(n_req)], seed=19)
    budgets = [2 + (i % 3) for i in range(n_req)]
    with use_policy(FP32):
        eng = ServeEngine(cfg, params, batch=2, cache_len=16, eos_id=-1,
                          sync_every=2, kv_layout="paged", page_size=8,
                          pool_pages=4)                 # 3 usable pages
        sched = SlotScheduler(2, eos_id=-1)
        for p, n in zip(prompts, budgets):
            sched.submit(p, max_new_tokens=n)
        summary = eng.serve(sched)
    pa = sched.pages
    assert summary["requests"] == n_req and summary["rejected"] == 0
    # drained: nothing leased, nothing leaked; any page still in use is
    # exactly a prefix-cache retention
    assert pa.leased == 0 and pa.leaked == 0
    assert pa.in_use == pa.cached
    assert summary["pages_leased"] == 0 and summary["pages_leaked"] == 0
    assert summary["pages_cached"] == pa.cached
    # free list and cache partition the pool: ids intact, no dupes
    cached_ids = sorted(p for p in pa._page_key if pa._refcount[p] == 0)
    assert sorted(list(pa._free) + cached_ids) == list(range(1, 4))
    assert sorted(pa._free_set) == sorted(pa._free)     # lockstep mirror
    assert summary["slot_refills"] >= n_req - 2
    assert 0 < summary["pages_peak_in_use"] <= pa.capacity
    # every request recorded a real allocation and matched its reference
    for r in sched.finished:
        assert r.pages and all(1 <= p < 4 for p in r.pages)
        assert r.tokens == _reference_decode(
            cfg, params, r.prompt, r.max_new_tokens, cache_len=16), r.rid


# ---------------------------------------------------------------------------
# unit: allocator + gather
# ---------------------------------------------------------------------------

def test_page_allocator_pure():
    pa = PageAllocator(6, page_size=4, max_request_pages=3,
                       min_request_tokens=6)
    assert pa.capacity == 5 and pa.free_pages == 5
    assert pa.pages_needed(1) == 2          # floored at min_request_tokens
    assert pa.pages_needed(9) == 3
    assert pa.fits_ever(12) and not pa.fits_ever(13)   # 4 pages > cap 3
    a = pa.alloc(3)
    assert a == [1, 2, 3] and pa.in_use == 3 and pa.peak_in_use == 3
    assert pa.alloc(3) is None              # free=2 < 3
    b = pa.alloc(2)
    assert b == [4, 5] and pa.free_pages == 0
    pa.free(a)
    assert pa.free_pages == 3 and pa.peak_in_use == 5
    with pytest.raises(AssertionError):
        pa.free([0])                        # the trash page is never freed
    with pytest.raises(AssertionError):
        pa.free([1])                        # double free


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

def _serve_fleet(cfg, params, prompts, budgets, *, greedy=True,
                 pool_pages=None, cache_len=32, page_size=8, batch=2,
                 tiers=None):
    eng = ServeEngine(cfg, params, batch=batch, cache_len=cache_len,
                      eos_id=-1, sync_every=2, kv_layout="paged",
                      page_size=page_size, pool_pages=pool_pages)
    sched = SlotScheduler(batch, eos_id=-1)
    for i, (p, n) in enumerate(zip(prompts, budgets)):
        sched.submit(p, max_new_tokens=n,
                     tier=tiers[i] if tiers else "premium")
    summary = eng.serve(sched, greedy=greedy)
    return sched, summary


def test_prefix_shared_system_prompt_fleet(dense_setup, monkeypatch):
    """A fleet sharing one 16-token system prompt prefills the shared span
    once: later admissions map the cached pages (prefix_hits), save their
    prefill tokens, and peak *leased* pages drop measurably — while every
    token stays bit-identical to the sharing-disabled run AND the batch-1
    references."""
    cfg, params = dense_setup
    rng = np.random.default_rng(29)
    system = rng.integers(0, cfg.vocab_size, 16).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size, 4).tolist()
               for _ in range(6)]
    budgets = [4] * 6
    with use_policy(FP32):
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "1")
        on, s_on = _serve_fleet(cfg, params, prompts, budgets)
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "0")
        off, s_off = _serve_fleet(cfg, params, prompts, budgets)
        refs = [_reference_decode(cfg, params, p, n, cache_len=32)
                for p, n in zip(prompts, budgets)]
    on_by = {r.rid: r for r in on.finished}
    off_by = {r.rid: r for r in off.finished}
    for rid, ref in enumerate(refs):
        assert on_by[rid].tokens == off_by[rid].tokens == ref, rid
    # first request registers; the other five hit the two whole pages
    assert s_on["prefix_hits"] == 5
    assert s_on["prefix_tokens_saved"] == 5 * 16
    assert "prefix_hits" not in s_off
    assert on_by[1].shared_tokens == 16 and off_by[1].shared_tokens == 0
    # sharing shrinks the lease high-water mark (satellite: peak tracks
    # every lease change, and cached retentions are not leases)
    assert s_on["pages_peak_in_use"] < s_off["pages_peak_in_use"]
    assert s_on["pages_leaked"] == 0 and s_off["pages_leaked"] == 0
    assert s_on["pages_leased"] == 0
    assert s_on["pages_cached"] > 0 and s_off["pages_cached"] == 0


def test_prefix_cow_fork_under_sampling(dense_setup, monkeypatch):
    """n>1 sampling of one prompt: every later admission tail-hits the
    first's cached partial page, COW-copies it into its own page, then the
    sampled continuations DIVERGE — each stream's decode writes land in its
    private fork. The engine consumes rng in the same order with sharing on
    and off, so the sampled streams must be token-identical: any COW
    corruption (a reader scribbling on the shared tail) would break it."""
    cfg, params = dense_setup
    rng = np.random.default_rng(31)
    prompt = rng.integers(0, cfg.vocab_size, 13).tolist()   # 1 page + tail 5
    prompts, budgets = [prompt] * 4, [6] * 4
    with use_policy(FP32):
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "1")
        on, s_on = _serve_fleet(cfg, params, prompts, budgets, greedy=False)
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "0")
        off, s_off = _serve_fleet(cfg, params, prompts, budgets, greedy=False)
    on_by = {r.rid: r for r in on.finished}
    off_by = {r.rid: r for r in off.finished}
    for rid in range(4):
        assert on_by[rid].tokens == off_by[rid].tokens, rid
    # identical full prompts: reqs 1..3 share 12 of 13 tokens via the tail
    # donor (the last prompt token always re-prefills for logits)
    assert s_on["prefix_hits"] == 3 and s_on["cow_forks"] == 3
    assert s_on["prefix_tokens_saved"] == 3 * 12
    # sampling actually diverged the forks (else COW went untested)
    assert len({tuple(on_by[r].tokens) for r in range(4)}) > 1


def test_prefix_cache_eviction_churn_leak_free(dense_setup):
    """Shared-prefix churn on a pool too small to cache every tail: idle
    cached runs evict under pressure while pinned (hit) runs survive; after
    the drain nothing is leased and nothing leaks, and every stream matched
    its reference."""
    cfg, params = dense_setup
    rng = np.random.default_rng(37)
    system = rng.integers(0, cfg.vocab_size, 8).tolist()
    prompts = [system + rng.integers(0, cfg.vocab_size, 4).tolist()
               for _ in range(8)]
    budgets = [4] * 8
    with use_policy(FP32):
        sched, summary = _serve_fleet(cfg, params, prompts, budgets,
                                      pool_pages=5, cache_len=16)
        refs = [_reference_decode(cfg, params, p, n, cache_len=16)
                for p, n in zip(prompts, budgets)]
    pa = sched.pages
    assert summary["requests"] == 8 and summary["rejected"] == 0
    assert summary["prefix_hits"] >= 6          # the system page stays hot
    assert summary["prefix_evictions"] > 0      # idle tails were reclaimed
    assert pa.leased == 0 and pa.leaked == 0
    assert summary["pages_leased"] == 0 and summary["pages_leaked"] == 0
    assert sorted(pa._free_set) == sorted(pa._free)
    by = {r.rid: r for r in sched.finished}
    for rid, ref in enumerate(refs):
        assert by[rid].tokens == ref, rid


def test_prefix_cache_tier_isolation(dense_setup, monkeypatch):
    """Premium and bulk streams never share a cached prefix: the cache key
    carries the tier, so one identical prompt served under both tiers
    registers two independent runs (2 hits among 4 requests, not 3) —
    the divergence-probe premium-identity guarantee cannot be laundered
    through a shared page."""
    cfg, params = dense_setup
    rng = np.random.default_rng(41)
    prompt = rng.integers(0, cfg.vocab_size, 12).tolist()
    prompts, budgets = [prompt] * 4, [4] * 4
    tiers = ["premium", "bulk", "premium", "bulk"]
    with use_policy(FP32):
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "1")
        on, s_on = _serve_fleet(cfg, params, prompts, budgets, tiers=tiers)
        monkeypatch.setenv("REPRO_PREFIX_CACHE", "0")
        off, s_off = _serve_fleet(cfg, params, prompts, budgets, tiers=tiers)
    assert s_on["prefix_hits"] == 2             # one per tier, never across
    on_by = {r.rid: r for r in on.finished}
    off_by = {r.rid: r for r in off.finished}
    for rid in range(4):
        assert on_by[rid].tokens == off_by[rid].tokens, rid
        assert on_by[rid].tier == tiers[rid]
    # hits paired within tier: each tier's second request shared the run
    shared_tiers = sorted(on_by[r].tier for r in range(4)
                          if on_by[r].shared_tokens)
    assert shared_tiers == ["bulk", "premium"]


def test_page_allocator_refcounts_and_prefix_index():
    """Pure host-side allocator: retain/release refcounts, cached-page
    parking, tier-keyed lookup, tail-donor semantics, LRU eviction of idle
    runs, and the leased-page high-water mark updating on every lease
    change (not just alloc)."""
    pa = PageAllocator(8, page_size=4, prefix_caching=True, fingerprint="t")
    prompt = list(range(10))                    # 2 whole pages + tail of 2
    pages = pa.alloc(3)
    assert pages == [1, 2, 3] and pa.leased == 3 and pa.peak_in_use == 3
    assert pa.prefix_register(prompt, pages, "premium") == 3
    # tier isolation + longest-run lookup with tail donor
    assert pa.prefix_lookup(prompt, "bulk") == ([], 0, None)
    hit, shared, donor = pa.prefix_lookup(prompt, "premium")
    assert hit == [1, 2] and shared == 9 and donor == 3
    # registrant retires: its pages park as cached, NOT freed or leaked
    pa.free(pages)
    assert pa.leased == 0 and pa.cached == 3 and pa.leaked == 0
    assert pa.free_pages == 4 and pa.in_use == 3
    # a reader pins the run with leases, allocs its remainder; the peak
    # notes the retain-driven lease growth (satellite: every lease change)
    pa.retain(hit + [donor])
    assert pa.leased == 3 and pa.cached == 0
    fresh = pa.alloc(1)
    assert fresh == [4] and pa.leased == 4 and pa.peak_in_use == 4
    pa.cow_fork(donor)                          # copy done: donor re-parks
    assert pa.cow_forks == 1 and pa.cached == 1 and pa.leased == 3
    pa.free(hit + fresh)
    assert pa.leased == 0 and pa.cached == 3 and pa.leaked == 0
    # a partially-pinned run never evicts; an idle one does (LRU)
    pa.retain([1])
    assert pa.allocatable({1}) == pa.free_pages == 4
    pa.free([1])
    assert pa.allocatable() == 7                # idle run is reclaimable
    big = pa.alloc(6)                           # forces eviction of the run
    assert big is not None and pa.prefix_evictions == 1
    assert pa.prefix_lookup(prompt, "premium") == ([], 0, None)
    pa.free(big)
    assert pa.leaked == 0 and pa.free_pages == 7
    # double free / retain-of-free still assert, now O(1) via the free-set
    with pytest.raises(AssertionError):
        pa.free([1])
    with pytest.raises(AssertionError):
        pa.retain([1])


def test_gather_pages_masks_unmapped_and_wiped():
    """Unmapped block entries must gather as empty (positions -1) with
    zeroed k/v — even when the trash page holds NaNs from a free slot's
    garbage decode row (0·NaN would otherwise poison the softmax)."""
    n_pages, psz, kvh, hd = 4, 2, 1, 2
    k = jnp.arange(n_pages * psz * kvh * hd, dtype=jnp.float32).reshape(
        n_pages, psz, kvh, hd)
    k = k.at[0].set(jnp.nan)                # trash page poisoned
    positions = jnp.array([[7, 8], [0, 1], [2, 3], [-1, -1]], jnp.int32)
    block = jnp.array([[1, 2], [3, -1]], jnp.int32)
    cache = PagedKVCache(k=k, v=k * 2, positions=positions,
                         block_table=block)
    kg, vg, pg = gather_pages(cache)
    assert kg.shape == (2, 4, kvh, hd)
    np.testing.assert_array_equal(np.asarray(pg),
                                  [[0, 1, 2, 3], [-1, -1, -1, -1]])
    # slot 1's unmapped tail gathers zeros, not the NaN trash page
    assert np.isfinite(np.asarray(kg)).all()
    assert (np.asarray(kg[1, 2:]) == 0).all()
    np.testing.assert_array_equal(np.asarray(kg[0, 0]),
                                  np.asarray(k[1, 0]))
