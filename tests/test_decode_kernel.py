"""Fused paged decode-attention kernel: bit-for-bit parity with the
gather+dense path (kernels/sa_decode_attention.py vs gather_pages +
decode_attention), across GQA ratios, window/softcap, precision formats,
grid-shape (ppb, hb) pins, staggered per-slot positions, partial block
tables, NaN-poisoned trash pages, and fully-empty slots. Parity is u32
equality, not allclose — the kernel is a data-movement change, and the knob
(REPRO_DECODE_ATTN) must A/B only the movement, never the numbers."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import given, settings, st
from repro.core import PrecisionPolicy, use_policy
from repro.kernels import ops
from repro.kernels.sa_decode_attention import (fused_decode_supported,
                                               largest_divisor)
from repro.models.layers import PagedKVCache, decode_attention, gather_pages

FP32 = PrecisionPolicy(input_format="fp32")


def _workload(seed, batch, kvh, g, hd, psz, max_pages, mapped,
              poison_trash=True, pos=None):
    """Synthetic pool + block tables; `mapped` is pages-per-slot (int or
    per-slot list). Trash page (id 0) NaN-poisoned by default so a masking
    bug in either path turns into a non-finite output, not a tiny error."""
    rng = np.random.default_rng(seed)
    mapped = [mapped] * batch if isinstance(mapped, int) else list(mapped)
    n_pages = batch * max_pages + 1
    q = jnp.asarray(rng.standard_normal((batch, 1, kvh * g, hd)),
                    jnp.float32)
    k = rng.standard_normal((n_pages, psz, kvh, hd)).astype(np.float32)
    v = rng.standard_normal((n_pages, psz, kvh, hd)).astype(np.float32)
    if poison_trash:
        k[0] = v[0] = np.nan
    pp = np.full((n_pages, psz), -1, np.int32)
    bt = np.full((batch, max_pages), -1, np.int32)
    for b in range(batch):
        pids = 1 + b * max_pages + np.arange(mapped[b])
        bt[b, :mapped[b]] = pids
        pp[pids] = np.arange(mapped[b] * psz, dtype=np.int32).reshape(
            mapped[b], psz)
    if pos is None:
        pos = [max(m * psz - 1, 0) for m in mapped]
    pos = jnp.asarray(pos, jnp.int32)
    return (q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(pp),
            jnp.asarray(bt), pos)


def _gather_ref(q, k, v, pp, bt, pos, **kw):
    return decode_attention(q, *gather_pages(PagedKVCache(k, v, pp, bt)),
                            pos, **kw)


def _assert_bit_equal(a, b, msg=""):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype == np.float32
    if not np.array_equal(a.view(np.uint32), b.view(np.uint32)):
        diff = np.abs(np.where(np.isnan(a), np.inf, a)
                      - np.where(np.isnan(b), np.inf, b))
        raise AssertionError(f"fused != gather {msg}: "
                             f"max abs diff {np.nanmax(diff)}")


# ---------------------------------------------------------------------------
# parity matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kvh,g", [(2, 4), (4, 1), (1, 4)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (5, 0.0), (0, 3.0),
                                        (7, 2.0)])
def test_bit_parity_gqa_window_softcap(kvh, g, window, cap):
    """GQA ratios (grouped / MHA / single-KV-head) × window × softcap: the
    kernel replicates decode_attention's masking and score epilogue under
    the SA contract exactly."""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(0, 2, kvh, g, 16, 4, 4,
                                         mapped=[3, 1])
        ref = _gather_ref(q, k, v, pp, bt, pos, window=window, cap=cap)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos,
                                         window=window, cap=cap)
    assert np.isfinite(np.asarray(out)).all()
    _assert_bit_equal(ref, out, f"kvh={kvh} g={g} w={window} cap={cap}")


@pytest.mark.parametrize("fmt,mode", [("fp32", "exact"), ("bf16", "exact"),
                                      ("fp16", "exact"), ("fp32", "approx"),
                                      ("bf16", "approx")])
def test_bit_parity_formats_and_modes(fmt, mode):
    """Reduced-precision input formats and the approximate-normalization
    (bulk-tier) mode: cast_in per page block in VMEM ≡ cast_in on the dense
    gathered view, and the guard-bit truncation lands at the same two spots
    as the dense sa_einsum."""
    pol = PrecisionPolicy(input_format=fmt, mode=mode)
    with use_policy(pol):
        q, k, v, pp, bt, pos = _workload(1, 2, 2, 2, 16, 4, 4,
                                         mapped=[4, 2])
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos)
    _assert_bit_equal(ref, out, f"fmt={fmt} mode={mode}")


@pytest.mark.parametrize("ppb", [1, 2, 4])
@pytest.mark.parametrize("hb", [1, 2])
def test_bit_parity_all_grid_shapes(ppb, hb):
    """Every (pages_per_block, heads_per_block) grid shape is numerics-
    invariant — autotuning can never change the answer. (Non-divisor pins
    are clipped; ppb=4 with P=4 is the single-step walk.)"""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(2, 2, 2, 2, 8, 4, 4,
                                         mapped=[2, 4])
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos, ppb=ppb,
                                         hb=hb)
    _assert_bit_equal(ref, out, f"ppb={ppb} hb={hb}")


def test_bit_parity_staggered_positions_partial_page():
    """Slots at unrelated decode depths (continuous batching) with the last
    page only partially written (tail positions -1): position masking in
    the kernel must match the gathered view's row for row."""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(3, 3, 2, 2, 16, 4, 4,
                                         mapped=[3, 1, 4],
                                         pos=[9, 2, 14])
        # slot 0's third page is half-empty: positions beyond 9 never
        # written; mark them -1 like a real mid-page decode state
        pp = np.array(pp)
        pp[3, 2:] = -1
        pp = jnp.asarray(pp)
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos)
    assert np.isfinite(np.asarray(out)).all()
    _assert_bit_equal(ref, out, "staggered")


def test_trash_page_nan_and_explicit_zero_entry():
    """A block table carrying an explicit 0 (the reserved trash page id)
    must be treated as unmapped by both paths even while the trash page is
    NaN everywhere — neither 0·NaN nor a gathered NaN row may leak."""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(4, 2, 2, 2, 16, 4, 4,
                                         mapped=[2, 2])
        bt = np.asarray(bt).copy()
        bt[0, 2] = 0                    # explicit trash-page entry
        bt = jnp.asarray(bt)
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos)
    assert np.isfinite(np.asarray(out)).all()
    assert np.isfinite(np.asarray(ref)).all()
    _assert_bit_equal(ref, out, "explicit page-0")


def test_empty_slot_yields_zeros_both_paths():
    """A slot with zero mapped pages (admitted but nothing written yet) has
    every score lane masked: the safe-softmax guard turns the would-be
    NaN row into exact zeros — in the kernel and in decode_attention."""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(5, 2, 2, 2, 16, 4, 4,
                                         mapped=[3, 0], pos=[11, 0])
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos)
    assert np.isfinite(np.asarray(out)).all()
    assert (np.asarray(out)[1] == 0.0).all()
    assert (np.asarray(ref)[1] == 0.0).all()
    _assert_bit_equal(ref, out, "empty slot")


def test_decode_attention_all_masked_rows_guarded():
    """Unit guard test on the dense path itself: a fully-empty cache slot
    (all kv_positions -1) must produce zeros, not NaN — the pre-guard
    softmax returned exp(-inf - -inf)/0."""
    B, S, kvh, g, hd = 2, 8, 2, 2, 4
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((B, 1, kvh * g, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, kvh, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, kvh, hd)), jnp.float32)
    kv_pos = jnp.asarray(
        np.stack([np.arange(S), np.full(S, -1)]), jnp.int32)
    with use_policy(FP32):
        o = decode_attention(q, kc, vc, kv_pos, jnp.asarray([7, 0],
                                                            jnp.int32))
    o = np.asarray(o)
    assert np.isfinite(o).all()
    assert (o[1] == 0.0).all() and not (o[0] == 0.0).all()


def test_fused_unsupported_policies_raise_and_report():
    """FP8 inputs / non-fp32 output formats are the gather path's job:
    `fused_decode_supported` says so and the kernel refuses loudly rather
    than silently diverging from the quantization machinery."""
    assert fused_decode_supported(FP32)
    assert fused_decode_supported(PrecisionPolicy(input_format="bf16"))
    fp8 = PrecisionPolicy(input_format="fp8_e4m3")
    assert not fused_decode_supported(fp8)
    out_rounded = PrecisionPolicy(input_format="bf16", output_format="bf16")
    assert not fused_decode_supported(out_rounded)
    q, k, v, pp, bt, pos = _workload(7, 1, 2, 2, 8, 4, 4, mapped=2)
    with pytest.raises(ValueError, match="fused paged decode"):
        ops.paged_decode_attention(q, k, v, pp, bt, pos, policy=fp8)


def test_largest_divisor():
    assert largest_divisor(8, 8) == 8
    assert largest_divisor(8, 5) == 4
    assert largest_divisor(7, 2) == 1
    assert largest_divisor(12, 9) == 6
    assert largest_divisor(3, 100) == 3


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 4), st.integers(0, 4),
       st.integers(0, 4))
def test_random_block_tables_property(seed, m0, m1, m2):
    """Property: for any random block-table occupancy (including empty and
    full slots) the fused walk and the dense gather agree bit-for-bit."""
    with use_policy(FP32):
        q, k, v, pp, bt, pos = _workload(seed % 1000, 3, 2, 2, 8, 4, 4,
                                         mapped=[m0, m1, m2])
        ref = _gather_ref(q, k, v, pp, bt, pos)
        out = ops.paged_decode_attention(q, k, v, pp, bt, pos)
    assert np.isfinite(np.asarray(out)).all()
    _assert_bit_equal(ref, out, f"mapped=({m0},{m1},{m2})")


# ---------------------------------------------------------------------------
# serve-level A/B: the knob changes nothing but the data movement
# ---------------------------------------------------------------------------

def test_serve_fused_equals_gather_tokens(monkeypatch):
    """End-to-end: a paged engine decoding with the fused kernel (default)
    and one decoding with REPRO_DECODE_ATTN=gather produce identical token
    streams through refills. Fresh engines per setting — the knob is read
    at trace time, so each engine's chunk fn lowers its own path."""
    import dataclasses

    from repro.configs import reduced_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.serve.scheduler import SlotScheduler

    cfg = dataclasses.replace(reduced_config("qwen2.5-14b"), remat=False)
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in
               (5, 9, 7)]
    budgets = [6, 3, 4]

    def run(impl):
        monkeypatch.setenv("REPRO_DECODE_ATTN", impl)
        with use_policy(FP32):
            eng = ServeEngine(cfg, params, batch=2, cache_len=32,
                              eos_id=-1, sync_every=2, kv_layout="paged",
                              page_size=8)
            sched = SlotScheduler(2, eos_id=-1)
            for p, n in zip(prompts, budgets):
                sched.submit(p, max_new_tokens=n)
            summary = eng.serve(sched)
        assert summary["decode_attn"] == impl
        return {r.rid: r.tokens for r in sched.finished}

    fused, gather = run("fused"), run("gather")
    assert fused == gather
    assert all(len(v) for v in fused.values())
