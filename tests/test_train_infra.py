"""Optimizer vs numpy reference, checkpoint roundtrip/reshard, compression,
data pipeline, fault handling."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import MemmapTokens, Prefetcher, SyntheticLM
from repro.parallel import compression as C
from repro.train import checkpoint as CKPT
from repro.train.fault import StragglerWatchdog
from repro.train.optimizer import AdamW, constant_lr, warmup_cosine


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def _np_adamw_step(p, g, m, v, t, lr, b1, b2, eps, wd, clip):
    gn = np.sqrt(sum((gi ** 2).sum() for gi in g.values()))
    scale = min(1.0, clip / max(gn, 1e-12))
    g = {k: gi * scale for k, gi in g.items()}
    out_p, out_m, out_v = {}, {}, {}
    for k in p:
        out_m[k] = b1 * m[k] + (1 - b1) * g[k]
        out_v[k] = b2 * v[k] + (1 - b2) * g[k] ** 2
        mh = out_m[k] / (1 - b1 ** t)
        vh = out_v[k] / (1 - b2 ** t)
        out_p[k] = p[k] - lr * (mh / (np.sqrt(vh) + eps) + wd * p[k])
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p = {"a": rng.standard_normal((4, 3)).astype(np.float32),
         "b": rng.standard_normal((7,)).astype(np.float32)}
    opt = AdamW(schedule=constant_lr(1e-2), b1=0.9, b2=0.95, eps=1e-8,
                weight_decay=0.1, clip_norm=1.0)
    state = opt.init(p)
    pj = jax.tree.map(jnp.asarray, p)
    m = {k: np.zeros_like(v) for k, v in p.items()}
    v = {k: np.zeros_like(vv) for k, vv in p.items()}
    for t in range(1, 4):
        g = {k: rng.standard_normal(vv.shape).astype(np.float32) * (t * 0.3)
             for k, vv in p.items()}
        pj, state, _ = opt.update(jax.tree.map(jnp.asarray, g), state, pj)
        p, m, v = _np_adamw_step(p, g, m, v, t, 1e-2, 0.9, 0.95, 1e-8, 0.1, 1.0)
        for k in p:
            np.testing.assert_allclose(np.asarray(pj[k]), p[k], rtol=2e-5,
                                       atol=2e-6)


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, rel=1e-5)
    assert float(s(5)) == pytest.approx(0.5, rel=1e-5)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-4)
    assert float(s(55)) > float(s(90))


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 3, t, extra={"note": "x"})
    restored, extra, step = CKPT.restore(str(tmp_path), t)
    assert step == 3 and extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_latest_pointer_and_multiple_steps(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 1, t)
    CKPT.save(str(tmp_path), 5, t)
    assert CKPT.latest_step(str(tmp_path)) == 5
    _, _, step = CKPT.restore(str(tmp_path), t, step=1)
    assert step == 1


def test_checkpoint_detects_corruption(tmp_path):
    t = _tree()
    d = CKPT.save(str(tmp_path), 2, t)
    victim = os.path.join(d, "arr_00000.npy")
    raw = bytearray(open(victim, "rb").read())
    raw[-1] ^= 0xFF
    open(victim, "wb").write(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        CKPT.restore(str(tmp_path), t)


def test_checkpoint_reshard_on_load(tmp_path):
    """Elastic restart: restore onto a different mesh layout."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    CKPT.save(str(tmp_path), 1, t)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _, _ = CKPT.restore(str(tmp_path), t, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


def test_async_saver(tmp_path):
    t = _tree()
    saver = CKPT.AsyncSaver()
    saver.save_async(str(tmp_path), 9, t)
    saver.wait()
    assert CKPT.latest_step(str(tmp_path)) == 9


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_compression_roundtrip_error_bounded(codec):
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32)),
         "b": jnp.asarray(rng.standard_normal((5,)).astype(np.float32))}
    payload, resid = C.compress_tree(g, codec)
    back = C.decompress_tree(payload, codec)
    for k in g:
        err = np.abs(np.asarray(back[k]) - np.asarray(g[k]))
        scale = np.abs(np.asarray(g[k])).max()
        bound = scale * (2 ** -8 if codec == "bf16" else 1 / 127)
        assert err.max() <= bound * 1.01
        # residual is exactly the quantization error
        np.testing.assert_allclose(np.asarray(resid[k]),
                                   np.asarray(g[k]) - np.asarray(back[k]),
                                   atol=1e-7)


def test_error_feedback_unbiased_over_time():
    """With error feedback, the accumulated applied update converges to the
    accumulated true gradient (residual stays bounded)."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    applied_sum = np.zeros(64, np.float32)
    resid = jnp.zeros(64)
    for _ in range(50):
        g = rng.standard_normal(64).astype(np.float32) * 0.1
        true_sum += g
        gj = jnp.asarray(g) + resid
        q, scale = C.quantize_int8(gj)
        back = C.dequantize_int8(q, scale)
        resid = gj - back
        applied_sum += np.asarray(back)
    # residual bounded by one quantization step, not growing
    assert (np.abs(applied_sum - true_sum).max()
            <= float(jnp.abs(resid).max()) + 1e-5)
    assert float(jnp.abs(resid).max()) < 0.05


# ---------------------------------------------------------------------------
# Data pipeline & fault handling
# ---------------------------------------------------------------------------

def test_synthetic_deterministic_and_host_disjoint():
    d = SyntheticLM(1000, 16, 4, seed=3)
    b1, b2 = d.batch_at(5, host=0), d.batch_at(5, host=0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_at(5, host=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_memmap_pipeline(tmp_path):
    path = str(tmp_path / "toks.bin")
    np.arange(10_000, dtype=np.int32).tofile(path)
    d = MemmapTokens(path, seq_len=32, batch_per_host=2, n_hosts=2, host=1)
    b = d.batch_at(0)
    assert b["tokens"].shape == (2, 32)
    np.testing.assert_array_equal(b["labels"], b["tokens"] + 1)  # arange data
    d0 = MemmapTokens(path, seq_len=32, batch_per_host=2, n_hosts=2, host=0)
    assert not np.array_equal(d0.batch_at(0)["tokens"], b["tokens"])


def test_prefetcher_yields_in_order():
    it = Prefetcher(iter([{"x": np.full(2, i)} for i in range(5)]), depth=2)
    got = [int(b["x"][0]) for b in it]
    assert got == list(range(5))


def test_straggler_watchdog_flags_slow_steps():
    events = []
    w = StragglerWatchdog(threshold=2.0, on_straggler=lambda *a: events.append(a))
    import time
    for i in range(8):
        w.step_start()
        time.sleep(0.012 if i == 6 else 0.001)
        w.step_end(i)
    assert len(w.events) >= 1 and w.events[0][0] == 6
    assert events == w.events
