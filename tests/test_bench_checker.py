"""Exit-code contract of benchmarks/check_bench_regression.py.

The checker is a CI gate, so its *failure* modes are load-bearing: a JSON
with no comparable rows (schema drift, renamed table) must exit 2 — not
"0 rows compared, pass" — and a baseline row missing from the new run
must WARN but not fail (bench legs shrink under --smoke). These tests pin
those paths; the happy path is covered end-to-end by the CI serve-smoke
job itself.
"""
from __future__ import annotations

import importlib.util
import json
import sys
from pathlib import Path

import pytest

_CHECKER = (Path(__file__).resolve().parent.parent / "benchmarks"
            / "check_bench_regression.py")


def _load_checker():
    spec = importlib.util.spec_from_file_location("check_bench_regression",
                                                  _CHECKER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _doc(rows):
    return {"version": 1, "rows": rows}


def _row(table, name, us):
    return {"table": table, "name": name, "tuned_us": us}


def _write(tmp_path, fname, rows):
    p = tmp_path / fname
    p.write_text(json.dumps(_doc(rows)))
    return str(p)


def test_unknown_table_exits_2(tmp_path, capsys):
    """Rows only under an unrecognized table = schema drift → exit 2."""
    chk = _load_checker()
    new = _write(tmp_path, "new.json",
                 [_row("not_a_table", "sa_matmul_2x256x512", 10.0)])
    with pytest.raises(SystemExit) as e:
        chk.load_rows(new)
    assert e.value.code == 2
    assert "no comparable rows" in capsys.readouterr().err


def test_spec_verify_table_is_compared(tmp_path):
    chk = _load_checker()
    assert "spec_verify" in chk.COMPARED_TABLES
    assert chk.RTOL_BY_TABLE["spec_verify"] >= 0.2
    new = _write(tmp_path, "new.json",
                 [_row("spec_verify", "sa_matmul_5x256x512", 10.0)])
    rows, ref = chk.load_rows(new)
    assert rows == {("spec_verify", "sa_matmul_5x256x512"): 10.0}
    assert ref is None


def test_no_overlap_returns_2(tmp_path, capsys):
    """Disjoint row sets (e.g. full-config run vs smoke baseline) → 2."""
    chk = _load_checker()
    new = _write(tmp_path, "new.json",
                 [_row("decode", "sa_matmul_1x256x512", 10.0)])
    base = _write(tmp_path, "base.json",
                  [_row("spec_verify", "sa_matmul_2x256x512", 10.0)])
    assert chk.main([new, base, "--no-normalize"]) == 2
    assert "no overlapping rows" in capsys.readouterr().err


def test_missing_baseline_row_warns_but_passes(tmp_path, capsys):
    """A baseline row absent from the new run warns; the overlap gates."""
    chk = _load_checker()
    shared = _row("spec_verify", "sa_matmul_2x256x512", 10.0)
    new = _write(tmp_path, "new.json", [shared])
    base = _write(tmp_path, "base.json",
                  [shared, _row("spec_verify", "sa_matmul_9x256x512", 12.0)])
    assert chk.main([new, base, "--no-normalize"]) == 0
    out = capsys.readouterr().out
    assert "WARN: baseline row" in out
    assert "sa_matmul_9x256x512" in out


def test_regression_beyond_table_rtol_fails(tmp_path):
    """spec_verify's widened rtol holds at +30% and trips past it."""
    chk = _load_checker()
    base = _write(tmp_path, "base.json",
                  [_row("spec_verify", "sa_matmul_5x256x512", 100.0)])
    ok = _write(tmp_path, "ok.json",
                [_row("spec_verify", "sa_matmul_5x256x512", 128.0)])
    bad = _write(tmp_path, "bad.json",
                 [_row("spec_verify", "sa_matmul_5x256x512", 140.0)])
    assert chk.main([ok, base, "--no-normalize", "--rtol", "0.2"]) == 0
    assert chk.main([bad, base, "--no-normalize", "--rtol", "0.2"]) == 1


def test_committed_baseline_has_spec_verify_rows():
    """The regenerated committed baseline actually carries the new table."""
    chk = _load_checker()
    rows, ref = chk.load_rows(str(_CHECKER.parent / "BENCH_baseline.json"))
    assert any(t == "spec_verify" for t, _ in rows)
    assert ref is not None  # machine-speed normalization stays available


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
