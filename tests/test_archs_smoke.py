"""Per-arch reduced-config smoke tests: one forward + one train step on CPU,
asserting output shapes and finiteness (deliverable (f))."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced_config
from repro.models import model as M
from repro.train.optimizer import AdamW, constant_lr
from repro.train.step import make_train_step
from repro.train.train_state import init_state


def _frontend(cfg, batch, rng):
    if cfg.family in ("vlm",) or cfg.is_encdec:
        return jax.random.normal(rng, (batch, cfg.frontend_tokens,
                                       cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_and_train_step(arch):
    cfg = reduced_config(arch)
    rng = jax.random.key(0)
    B, T = 2, 16
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    fe = _frontend(cfg, B, jax.random.key(2))

    logits, _, _ = M.forward(M.init_params(rng, cfg), cfg,
                             toks, frontend_embeds=fe)
    exp_T = T + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_T, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())

    opt = AdamW(schedule=constant_lr(1e-3))
    step = make_train_step(cfg, opt, accum_steps=2)
    state = init_state(rng, cfg, opt)
    batch = {"tokens": toks, "labels": toks}
    if fe is not None:
        batch["frontend"] = fe
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state2.step) == 1
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, state2.params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_structure(arch):
    """Full (unreduced) configs: structural invariants only (no alloc)."""
    cfg = get_config(arch)
    assert cfg.num_layers % cfg.stack_period == 0
    assert cfg.padded_vocab >= cfg.vocab_size
    if cfg.num_heads > 1:
        assert cfg.num_heads % cfg.num_kv_heads == 0
    import math
    abstract = M.abstract_params(cfg)
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(abstract))
    target = cfg.param_count()
    assert abs(n - target) / target < 0.05   # counts match the formula
