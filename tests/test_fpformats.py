"""Reduced-precision format descriptors + quantization properties."""
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-stub shim

from repro.core.fpformats import (BF16, FP8_E4M3, FP8_E5M2, FP16, FORMATS,
                                  compose, decompose, get_format, quantize_np)


def test_format_constants_match_fig1():
    assert (BF16.exp_bits, BF16.man_bits) == (8, 7)
    assert (FP16.exp_bits, FP16.man_bits) == (5, 10)
    assert (FP8_E4M3.exp_bits, FP8_E4M3.man_bits) == (4, 3)
    assert (FP8_E5M2.exp_bits, FP8_E5M2.man_bits) == (5, 2)
    assert FP8_E4M3.max_finite == 448.0           # OCP FP8 spec
    assert FP8_E5M2.max_finite == 57344.0
    assert BF16.emax == 127 and BF16.emin == -126


@pytest.mark.parametrize("fmt", ["bf16", "fp16", "fp8_e4m3", "fp8_e5m2"])
def test_quantize_idempotent(fmt):
    rng = np.random.default_rng(0)
    x = rng.standard_normal(512).astype(np.float32) * 7
    q1 = quantize_np(x, fmt)
    q2 = quantize_np(q1, fmt)
    np.testing.assert_array_equal(q1, q2)


@settings(max_examples=150, deadline=None)
@given(st.floats(allow_nan=False, allow_infinity=False, width=32),
       st.sampled_from(["bf16", "fp8_e4m3", "fp8_e5m2", "fp16"]))
def test_quantize_error_bound_and_monotonic(x, fmt_name):
    fmt = get_format(fmt_name)
    q = float(quantize_np(np.float32(x), fmt))
    if abs(x) > fmt.max_finite:
        if fmt.saturate:
            assert abs(q) == fmt.max_finite
        else:
            assert np.isinf(q) or abs(q) == pytest.approx(fmt.max_finite)
    elif abs(x) < fmt.min_normal:
        assert q == 0.0                            # FTZ
    else:
        assert abs(q - x) <= 2.0 ** -fmt.man_bits * abs(x) * 0.5 * 1.0001
        assert np.sign(q) == np.sign(x) or q == 0


@pytest.mark.parametrize("fmt", ["bf16", "fp8_e4m3"])
def test_decompose_compose_roundtrip(fmt):
    fmt = get_format(fmt)
    rng = np.random.default_rng(1)
    x = quantize_np(rng.standard_normal(256).astype(np.float32), fmt)
    s, e, m = decompose(x, fmt)
    np.testing.assert_array_equal(compose(s, e, m, fmt), x)


def test_bf16_matches_jnp_cast():
    import jax.numpy as jnp
    rng = np.random.default_rng(2)
    x = rng.standard_normal(1024).astype(np.float32) * 100
    ours = quantize_np(x, "bf16")
    jnp_ = np.asarray(jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32))
    np.testing.assert_array_equal(ours, jnp_)


def test_registry():
    assert set(FORMATS) == {"fp32", "bf16", "fp16", "fp8_e4m3", "fp8_e5m2"}
    with pytest.raises(ValueError):
        get_format("fp4")
