"""Continuous-batching semantics: slot refill, EOS mid-batch, per-slot
positions vs single-sequence reference decode, dropless-MoE dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PrecisionPolicy, use_policy
from repro.configs import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import SlotScheduler

FP32 = PrecisionPolicy(input_format="fp32")


def _cfg(name="qwen2.5-14b"):
    return dataclasses.replace(reduced_config(name), remat=False)


def _reference_decode(cfg, params, prompt, n, eos_id=-1, cache_len=64):
    """Independent batch-1 greedy decode straight through M.forward."""
    prompt = jnp.asarray(prompt, jnp.int32)[None]
    plen = prompt.shape[1]
    cache = M.init_cache(cfg, 1, cache_len, dtype=jnp.float32)
    logits, cache, _ = M.forward(params, cfg, prompt, cache=cache,
                                 last_only=True)
    tok = int(np.asarray(jnp.argmax(logits[0, -1])))
    out = [tok]
    for i in range(n - 1):
        if tok == eos_id:
            break
        logits, cache, _ = M.forward(
            params, cfg, jnp.asarray([[tok]], jnp.int32), cache=cache,
            pos=jnp.full((1,), plen + i, jnp.int32))
        tok = int(np.asarray(jnp.argmax(logits[0, -1])))
        out.append(tok)
    if eos_id in out:                     # truncate after the first EOS
        out = out[:out.index(eos_id) + 1]
    return out


@pytest.fixture(scope="module")
def dense_setup():
    cfg = _cfg()
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def _prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).tolist() for n in lens]


def test_slot_refill_with_per_slot_positions(dense_setup):
    """A finished slot is refilled while the other slot keeps decoding;
    every request must match its single-sequence reference exactly —
    which is only possible if each slot keys the cache and RoPE on its own
    (B,) position, not a shared scalar."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [5, 9, 7, 11])
    budgets = [20, 4, 6, 5]
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=64,
                             eos_id=-1, sync_every=2)
        sched = SlotScheduler(2, eos_id=-1)
        for p, n in zip(prompts, budgets):
            sched.submit(p, max_new_tokens=n)
        summary = engine.serve(sched)
        refs = [_reference_decode(cfg, params, p, n)
                for p, n in zip(prompts, budgets)]
    by_rid = {r.rid: r for r in sched.finished}
    assert len(by_rid) == 4
    for rid, ref in enumerate(refs):
        assert by_rid[rid].tokens == ref, f"request {rid} diverged"
    assert summary["slot_refills"] >= 2
    # request 1 (4 tokens) retired early and its slot was refilled while
    # request 0 (20 tokens) was still mid-decode in the other slot
    assert by_rid[1].t_done < by_rid[0].t_done
    later = [r for r in sched.finished
             if r.t_admitted > by_rid[1].t_done - 1e-9 and r.rid != 1]
    assert later and any(r.t_admitted < by_rid[0].t_done for r in later)


def test_eos_mid_batch_frees_slot(dense_setup):
    """An EOS in one slot truncates that request and frees the slot while
    the neighbour slot keeps decoding; post-EOS chunk tokens never land."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [6, 8], seed=3)
    with use_policy(FP32):
        probe = _reference_decode(cfg, params, prompts[1], 10)
        eos = probe[2]          # the 3rd token the model really emits
        engine = ServeEngine(cfg, params, batch=2, cache_len=64,
                             eos_id=eos, sync_every=4)
        sched = SlotScheduler(2, eos_id=eos)
        reqA = sched.submit(prompts[0], max_new_tokens=12)
        reqB = sched.submit(prompts[1], max_new_tokens=12)
        engine.serve(sched)
        refs = [_reference_decode(cfg, params, p, 12, eos_id=eos)
                for p in prompts]
    assert reqB.tokens == refs[1] and reqB.tokens[-1] == eos
    assert reqB.finish_reason == "eos" and reqB.n_generated == 3
    assert reqA.tokens == refs[0]
    assert reqA.n_generated >= reqB.n_generated
    assert reqA.t_done >= reqB.t_done


def test_generate_matches_continuous_serve(dense_setup):
    """Static-batch generate ≡ continuous serve for lock-step requests."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [8, 8], seed=5)
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=32, eos_id=-1,
                             sync_every=3)
        out = np.asarray(engine.generate(jnp.asarray(prompts, jnp.int32), 6))
        sched = SlotScheduler(2, eos_id=-1)
        for p in prompts:
            sched.submit(p, max_new_tokens=6)
        engine.serve(sched)
    by_rid = {r.rid: r for r in sched.finished}
    for rid in (0, 1):
        assert by_rid[rid].tokens == out[rid].tolist()


def test_scheduler_bookkeeping_pure():
    """Host-side slot-table semantics, no jax: refill, EOS truncation,
    token-budget truncation, queue depth."""
    sched = SlotScheduler(2, eos_id=99)
    r0 = sched.submit([1, 2, 3], max_new_tokens=5)
    r1 = sched.submit([4, 5], max_new_tokens=2)
    r2 = sched.submit([6], max_new_tokens=3, arrival_time=0.0)
    assert sched.free_slots() == [0, 1]
    assert sched.admit(0, now=0.0) is r0 and sched.admit(1, now=0.0) is r1
    sched.start(0, first_token=10, now=0.1)
    sched.start(1, first_token=11, now=0.1)
    # next decode consumes the first generated token at pos == prompt_len
    assert sched.positions().tolist() == [3, 2]
    # chunk of 3 steps: r1 hits its 2-token budget at step 0; its later
    # chunk rows (and the EOS-looking 99s in them) must be discarded
    chunk = np.array([[20, 30], [21, 99], [99, 31]], np.int32)
    sched.observe(chunk, now=0.5)
    assert r1.tokens == [11, 30] and r1.finish_reason == "length"
    assert r0.tokens == [10, 20, 21, 99] and r0.finish_reason == "eos"
    assert sched.free_slots() == [0, 1] and sched.num_active() == 0
    assert sched.admit(0, now=1.0) is r2 and sched.refills == 1
    sched.start(0, first_token=99, now=1.1)       # EOS as the first token
    assert r2.finish_reason == "eos" and r2.n_generated == 1
    assert sched.drained()
    s = sched.summary()
    assert s["generated_tokens"] == 4 + 2 + 1
    assert s["eos_finishes"] == 2 and s["slot_refills"] == 1


def test_frozen_clock_arrivals_fast_forward(dense_setup):
    """An injected non-advancing clock must not hang the serve loop on
    future arrivals: engine time fast-forwards to the next arrival, so
    latency tests can be fully deterministic."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [6, 6], seed=11)
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=32,
                             eos_id=-1, sync_every=2)
        sched = SlotScheduler(2, eos_id=-1)
        sched.submit(prompts[0], max_new_tokens=3, arrival_time=5.0)
        sched.submit(prompts[1], max_new_tokens=3, arrival_time=9.0)
        summary = engine.serve(sched, clock=lambda: 0.0)
    assert summary["requests"] == 2
    # TTFT is measured on fast-forwarded engine time: admission happens
    # exactly at each arrival, so TTFT collapses to the prefill instant
    assert all(r.ttft == 0.0 for r in sched.finished)
    assert all(r.t_admitted in (5.0, 9.0) for r in sched.finished)


def test_oversized_request_rejected(dense_setup):
    """prompt_len + max_new_tokens beyond cache_len would wrap the global
    KV ring and silently corrupt output — the request is retired as
    rejected while the rest of the batch keeps serving."""
    cfg, params = dense_setup
    prompts = _prompts(cfg, [12, 6], seed=13)
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=16, eos_id=-1,
                             sync_every=2)
        sched = SlotScheduler(2, eos_id=-1)
        bad = sched.submit(prompts[0], max_new_tokens=8)    # 12+8 > 16
        good = sched.submit(prompts[1], max_new_tokens=4)
        summary = engine.serve(sched)
        ref = _reference_decode(cfg, params, prompts[1], 4, cache_len=16)
    assert bad.finish_reason == "rejected" and bad.tokens == []
    assert good.tokens == ref
    assert summary["rejected"] == 1 and summary["requests"] == 2


def test_scheduler_admission_is_fifo_among_arrived():
    """A late submit with an early arrival must not be head-of-line
    blocked behind a queued future arrival."""
    sched = SlotScheduler(1, eos_id=-1)
    late = sched.submit([1], 1, arrival_time=10.0)
    early = sched.submit([2], 1, arrival_time=0.0)
    assert sched.next_arrival() == 0.0
    assert sched.admit(0, now=0.0) is early
    assert sched.admit(0, now=0.0) is None      # `late` hasn't arrived
    sched.start(0, first_token=5, now=0.0)      # retires early (budget 1)
    assert sched.admit(0, now=10.0) is late


def test_decode_candidates_gated_on_m():
    """GEMV candidates sweep only when the whole M side fits one block;
    training-M sweeps must not pay their compiles."""
    from repro.kernels.autotune import candidates_for
    assert all(bm <= 32 for bm, _, _ in candidates_for(4, 512, 512))
    assert all(bm > 32 for bm, _, _ in candidates_for(1024, 1024, 1024))


def test_dropless_matches_capacity_when_nothing_drops():
    """With capacity ≥ T no token drops, so the GShard dispatch and the
    dense dropless dispatch must agree — they are the same math."""
    from repro.models.moe import moe_ffn
    cfg = _cfg("granite-moe-3b-a800m")
    rng = jax.random.key(0)
    d, E, F = cfg.d_model, cfg.num_experts, cfg.d_ff
    ks = jax.random.split(rng, 5)
    p = {"router": jax.random.normal(ks[0], (d, E)) * 0.1,
         "wg": jax.random.normal(ks[1], (E, d, F)) * 0.1,
         "wu": jax.random.normal(ks[2], (E, d, F)) * 0.1,
         "wd": jax.random.normal(ks[3], (E, F, d)) * 0.1}
    x = jax.random.normal(ks[4], (2, 4, d))
    with use_policy(FP32):
        cap, aux_c = moe_ffn(x, p, cfg, capacity_factor=float(E))
        drop, aux_d = moe_ffn(x, p, cfg, dropless=True)
    np.testing.assert_allclose(np.asarray(cap), np.asarray(drop),
                               rtol=1e-5, atol=1e-5)
    for k in aux_c:
        np.testing.assert_allclose(np.asarray(aux_c[k]),
                                   np.asarray(aux_d[k]), rtol=1e-6)


# ---------------------------------------------------------------------------
# self-speculative decoding (DESIGN.md §9)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def spec_setup():
    """Two-superblock reduced stack: the minimum where an early-exit draft
    (first superblock) differs from the verify forward (both)."""
    cfg = dataclasses.replace(
        reduced_config("qwen2.5-14b", layers_per_period=2), remat=False)
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
    return cfg, params


def _spec_serve(cfg, params, prompts, budgets, *, eos_id=-1, spec_k=0,
                draft_layers=None, kv_layout="paged", sync_every=4):
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=64,
                             eos_id=eos_id, sync_every=sync_every,
                             kv_layout=kv_layout, spec_k=spec_k,
                             spec_draft_layers=draft_layers)
        sched = SlotScheduler(2, eos_id=eos_id)
        for p, n in zip(prompts, budgets):
            sched.submit(p, max_new_tokens=n)
        summary = engine.serve(sched)
    return engine, sched, summary


@pytest.mark.parametrize("kv_layout", ["paged", "ring"])
@pytest.mark.parametrize("draft_layers", [1, 2])
def test_spec_greedy_identical_to_plain(spec_setup, kv_layout, draft_layers):
    """The exactness contract: greedy spec decoding emits the same tokens
    as the plain chunked scan, token for token, whatever the draft depth
    or acceptance rate — rejected drafts cost wall time, never output.
    draft_layers=2 (= the whole stack) is the accept-everything degenerate
    case; draft_layers=1 is a real early exit with mixed acceptance."""
    cfg, params = spec_setup
    prompts = _prompts(cfg, [5, 9, 7], seed=17)
    budgets = [10, 12, 8]          # 3 requests / 2 slots: refill mid-serve
    _, plain, _ = _spec_serve(cfg, params, prompts, budgets,
                              kv_layout=kv_layout, spec_k=0)
    eng, spec, summary = _spec_serve(cfg, params, prompts, budgets,
                                     kv_layout=kv_layout, spec_k=4,
                                     draft_layers=draft_layers)
    assert eng.spec_decoding_on()
    plain_by = {r.rid: r.tokens for r in plain.finished}
    spec_by = {r.rid: r.tokens for r in spec.finished}
    assert spec_by == plain_by
    assert spec.spec_drafted > 0
    if draft_layers == 2:          # draft stack == verify stack
        assert summary["spec_accept_rate"] == 1.0


def test_spec_staggered_slots_mixed_accept_lengths(spec_setup):
    """Slots sit at different depths (different prompt lengths, refills),
    and each resolves its own accept length per iteration — the per-slot
    `acc` indexes the rollback independently. Random init + a real early
    exit gives a mix of accept lengths including full rejection."""
    cfg, params = spec_setup
    prompts = _prompts(cfg, [4, 11, 6, 9], seed=23)
    budgets = [12, 10, 8, 12]
    _, plain, _ = _spec_serve(cfg, params, prompts, budgets, spec_k=0)
    _, spec, _ = _spec_serve(cfg, params, prompts, budgets, spec_k=4,
                             draft_layers=1)
    assert ({r.rid: r.tokens for r in spec.finished}
            == {r.rid: r.tokens for r in plain.finished})
    # the histogram actually spans lengths: not accept-all, not reject-all
    assert len(spec.spec_accept_hist) >= 2


def test_spec_reject_all_falls_back_to_one_token(spec_setup):
    """When the verify rejects every draft the iteration still makes
    progress: the verify's own first row is a normal decode step, so one
    token lands (`acc = 0` → emit targets[:, 0] only)."""
    cfg, params = spec_setup
    prompts = _prompts(cfg, [6, 8], seed=29)
    _, plain, _ = _spec_serve(cfg, params, prompts, [8, 8], spec_k=0)
    _, spec, summary = _spec_serve(cfg, params, prompts, [8, 8], spec_k=4,
                                   draft_layers=1)
    # random init: a depth-1 draft almost never matches the full stack
    # over a 503-way vocab, so reject-all iterations definitely occurred
    assert spec.spec_accept_hist.get(0, 0) > 0
    assert ({r.rid: r.tokens for r in spec.finished}
            == {r.rid: r.tokens for r in plain.finished})
    assert summary["spec_accept_rate"] < 1.0
    assert summary["generated_tokens"] == 16      # progress despite rejects


def test_spec_eos_mid_draft_truncates_and_frees(spec_setup):
    """EOS landing inside an accepted draft block: tokens after the EOS in
    the same block are discarded, the request retires with finish_reason
    'eos', and its pages return to the pool (nothing leaks)."""
    cfg, params = spec_setup
    prompts = _prompts(cfg, [6, 8], seed=31)
    with use_policy(FP32):
        probe = _reference_decode(cfg, params, prompts[1], 10)
    eos = probe[2]                 # 3rd emitted token: mid spec block
    _, plain, _ = _spec_serve(cfg, params, prompts, [12, 12], eos_id=eos,
                              spec_k=0)
    _, spec, summary = _spec_serve(cfg, params, prompts, [12, 12],
                                   eos_id=eos, spec_k=4, draft_layers=2)
    spec_by = {r.rid: r for r in spec.finished}
    plain_by = {r.rid: r for r in plain.finished}
    assert spec_by[1].tokens == plain_by[1].tokens
    assert spec_by[1].finish_reason == "eos"
    assert spec_by[1].tokens[-1] == eos
    # spec_k=4, draft_layers = full stack → the whole 5-token block was
    # accepted; everything past the EOS at index 2 must have been dropped
    assert spec_by[1].n_generated == 3
    assert spec_by[0].tokens == plain_by[0].tokens
    assert summary["pages_leaked"] == 0


def test_spec_chunk_jit_key_includes_spec_k(spec_setup):
    """Regression: the chunk closure cache must key on spec_k next to
    (steps, greedy, mode) — a 1-iteration spec chunk and a 1-step plain
    chunk would otherwise collide and serve each other's traced fn."""
    cfg, params = spec_setup
    with use_policy(FP32):
        engine = ServeEngine(cfg, params, batch=2, cache_len=64,
                             eos_id=-1, spec_k=4)
        plain = engine._chunk_fn(1, True)
        spec = engine._spec_chunk_fn(1, True, "exact", 4)
    assert plain is not spec
    assert set(engine._chunks) == {(1, True, "exact", 0),
                                   (1, True, "exact", 4)}


def test_spec_gating_auto_disables(spec_setup):
    """spec_decoding_on() refuses configurations the math can't support:
    spec_k=0, a single-superblock stack (no early exit), a ring shorter
    than the verify block, and the REPRO_SPEC_DECODE kill switch."""
    cfg2, params2 = spec_setup
    cfg1 = dataclasses.replace(reduced_config("qwen2.5-14b"), remat=False)
    with use_policy(FP32):
        params1 = M.init_params(jax.random.key(0), cfg1)
        assert not ServeEngine(cfg2, params2, batch=2, cache_len=64,
                               eos_id=-1, spec_k=0).spec_decoding_on()
        assert not ServeEngine(cfg1, params1, batch=2, cache_len=64,
                               eos_id=-1, spec_k=4).spec_decoding_on()
        on = ServeEngine(cfg2, params2, batch=2, cache_len=64, eos_id=-1,
                         spec_k=4)
        assert on.spec_decoding_on()
    import os
    os.environ["REPRO_SPEC_DECODE"] = "0"
    try:
        assert not on.spec_decoding_on()
    finally:
        del os.environ["REPRO_SPEC_DECODE"]


def test_tune_spec_verify_covers_decode_and_verify_m():
    """The pre-seed sweeps exactly the two Ms the spec chunk runs at:
    the per-token rows (M = batch) and the folded verify (batch·(k+1))."""
    from repro.kernels.autotune import tune_spec_verify
    got = tune_spec_verify(128, 64, 2, 4, dtype="float32", reps=1)
    assert set(got) == {2, 10}
    assert all(len(b) == 3 for b in got.values())


def test_staggered_positions_decode_vector(dense_setup):
    """Direct (B,) position-vector check: two sequences decoded at
    *different* depths in one batch match their batch-1 references."""
    cfg, params = dense_setup
    rng = np.random.default_rng(7)
    pA = rng.integers(0, cfg.vocab_size, 6).tolist()
    pB = rng.integers(0, cfg.vocab_size, 9).tolist()
    with use_policy(FP32):
        refA = _reference_decode(cfg, params, pA, 4, cache_len=32)
        refB = _reference_decode(cfg, params, pB, 4, cache_len=32)
        # batched: prefill each prompt alone, splice into a 2-row cache
        engine = ServeEngine(cfg, params, batch=2, cache_len=32, eos_id=-1)
        cache = engine.new_cache()
        toks, poss = [], []
        for slot, prompt in enumerate((pA, pB)):
            frag = engine.new_cache(batch=1)
            logits, frag = engine._prefill(
                params, jnp.asarray(prompt, jnp.int32)[None], frag, None)
            cache = engine._insert(cache, frag, slot)
            toks.append(int(np.asarray(jnp.argmax(logits[0, -1]))))
            poss.append(len(prompt))
        tok = jnp.asarray(toks, jnp.int32)
        pos = jnp.asarray(poss, jnp.int32)
        got = [[t] for t in toks]
        for _ in range(3):
            logits, cache, _ = M.forward(params, cfg, tok[:, None],
                                         cache=cache, pos=pos)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            pos = pos + 1
            for b, t in enumerate(np.asarray(tok)):
                got[b].append(int(t))
    assert got[0] == refA and got[1] == refB
