"""Distribution correctness on a multi-device host mesh.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps seeing 1 device (required: smoke tests and
benches must not inherit the fake-device setting)."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.parallel import sharding as S
from repro.parallel.compression import compressed_psum
from repro.train.optimizer import AdamW, constant_lr
from repro.train.step import make_train_step
from repro.train.train_state import TrainState, init_state

assert len(jax.devices()) == 8
cfg = reduced_config("gemma2-9b")
opt = AdamW(schedule=constant_lr(1e-3))
step = make_train_step(cfg, opt, accum_steps=2)
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}

# single-device reference
state0 = init_state(jax.random.key(0), cfg, opt)
_, m_ref = jax.jit(step)(state0, batch)

# 4x2 (data, model) mesh
mesh = jax.make_mesh((4, 2), ("data", "model"))
S.set_active_mesh(mesh)
state = init_state(jax.random.key(0), cfg, opt)
pshard = S.param_shardings(cfg, state.params, mesh)
repl = NamedSharding(mesh, P())
sshard = TrainState(step=repl, params=pshard,
                    opt_state=type(state.opt_state)(count=repl, mu=pshard, nu=pshard))
state = jax.device_put(state, sshard)
dshard = {k: NamedSharding(mesh, S.data_specs(mesh, v.shape)) for k, v in batch.items()}
batch_s = jax.device_put(batch, dshard)
with mesh:
    state2, m = jax.jit(step, in_shardings=(sshard, dshard),
                        out_shardings=(sshard, None))(state, batch_s)

# sharded == unsharded (same math, different layout)
ok_loss = abs(float(m["loss"]) - float(m_ref["loss"])) < 5e-3

# shard_map compressed gradient psum across the data axis
from jax.experimental.shard_map import shard_map
g_local = jnp.arange(32, dtype=jnp.float32).reshape(4, 8) * 0.01
def sync(g):
    summed, resid = compressed_psum({"g": g}, "data", codec="int8")
    return summed["g"], resid["g"]
f = shard_map(sync, mesh=mesh, in_specs=P("data", None),
              out_specs=(P("data", None), P("data", None)))
summed, resid = f(g_local)
true = np.tile(np.asarray(g_local).reshape(4, 1, 8).sum(0), (4, 1))
err = np.abs(np.asarray(summed) - true).max()
ok_comp = err < 0.05

print(json.dumps({"ok_loss": ok_loss, "loss": float(m["loss"]),
                  "loss_ref": float(m_ref["loss"]), "ok_comp": bool(ok_comp),
                  "comp_err": float(err)}))
"""


@pytest.mark.slow
def test_sharded_train_step_and_compressed_psum():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok_loss"], res
    assert res["ok_comp"], res


_PAGED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
# pin the fused Pallas page-walk kernel: the decode cell must lower it
# under SPMD, not silently fall back to the gather path
os.environ["REPRO_DECODE_ATTN"] = "fused"
import json
import jax
from jax.sharding import PartitionSpec as P
from repro.configs import reduced_config
from repro.models.config import ShapeCfg
from repro.models.layers import PagedKVCache
from repro.launch import specs as SP
from repro.parallel.sharding import set_active_mesh

mesh = jax.make_mesh((4, 2), ("data", "model"))
set_active_mesh(mesh)
cfg = reduced_config("qwen2.5-14b")
shape = ShapeCfg("decode_paged_smoke", 256, 8, "decode")
step_fn, args, in_sh, out_sh = SP.input_specs(cfg, shape, mesh,
                                              kv_layout="paged",
                                              page_size=64)
# the pool's page dim must shard over the data axis; block table replicated
pools = [s for s in jax.tree.leaves(
             in_sh[2], is_leaf=lambda x: isinstance(x, PagedKVCache))
         if isinstance(s, PagedKVCache)]
assert pools, "decode cell lowered without a paged leaf"
k_spec = pools[0].k.spec
bt_spec = pools[0].block_table.spec
ok_pages = k_spec[1] == ("data",) and bt_spec == P(None, None, None)
with mesh:
    jitted = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(2,))
    compiled = jitted.lower(*args).compile()
print(json.dumps({"ok_pages": bool(ok_pages), "k_spec": str(k_spec),
                  "n_devices": int(mesh.devices.size),
                  "hlo_chars": len(compiled.as_text())}))
"""


@pytest.mark.slow
def test_paged_decode_cell_lowers_on_mesh():
    """The paged decode cell (global page pool sharded over `data`, KV
    heads over `model`, replicated block table) must lower and compile on
    a multi-device host mesh — the serving analogue of the dry-run. Runs
    with REPRO_DECODE_ATTN=fused pinned, so the fused Pallas page-walk
    kernel itself must partition (batch over `data`, KV heads over
    `model`), not just the jnp gather fallback."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _PAGED_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok_pages"], res
    assert res["hlo_chars"] > 0


_HANDOFF_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import reduced_config
from repro.models import model as M
from repro.models.layers import KVCache, PagedKVCache
from repro.parallel import sharding as S
from repro.serve.engine import ServeEngine
from repro.train.step import make_prefill_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = reduced_config("qwen2.5-14b")
psz = 8
pool_pages = 12            # % data size (4) == 0: the page dim must shard
B, T = 2, 64
cache = M.init_cache(cfg, B, T, dtype=jnp.float32, paged=(pool_pages, psz))

# the staged fragment: a real batch-1 prefill run (what the prefill pool
# hands off at a two-pool completion); 19 tokens -> 3 pages
plen = 19
cap = -(-plen // psz) * psz
params = M.init_params(jax.random.key(0), cfg)
toks = jax.random.randint(jax.random.key(1), (1, plen), 0, cfg.vocab_size,
                          dtype=jnp.int32)
_, frag = jax.jit(make_prefill_step(cfg))(
    params, toks, M.init_cache(cfg, 1, cap, dtype=jnp.float32))
row = np.full((T // psz,), -1, np.int32)
row[:3] = [2, 3, 4]
row = jnp.asarray(row)
slot = jnp.asarray(1, jnp.int32)
keep = jnp.asarray(0, jnp.int32)

# unsharded reference: the unified engine's fused in-place insert
ref = jax.jit(ServeEngine._insert_impl)(cache, frag, slot, row, keep)

# pool sharding: page dim over the data axis, block table replicated
cspecs = S.cache_specs(cfg, cache, mesh, B)
pool = [s for s in jax.tree.leaves(
            cspecs, is_leaf=lambda x: isinstance(x, PagedKVCache))
        if isinstance(s, PagedKVCache)][0]
ok_pool = pool.k[1] == ("data",) and pool.block_table == P(None, None, None)

# fragment sharding: token dim REPLICATED over data (whole-page handoff —
# each data shard keeps its local pages at the scatter), heads over model
fspecs = S.handoff_frag_specs(cfg, frag, mesh)
kv = [s for s in jax.tree.leaves(
          fspecs, is_leaf=lambda x: isinstance(x, KVCache))
      if isinstance(s, KVCache)][0]
ok_frag = ("data" not in jax.tree.leaves(tuple(kv.k))
           and "model" in jax.tree.leaves(tuple(kv.k)))

# reshard_handoff is layout-only: bit-identical content
frag_s = S.reshard_handoff(frag, mesh, cfg)
ok_reshard = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(jax.tree.leaves(frag),
                                 jax.tree.leaves(frag_s)))

# the same insert under SPMD on the sharded pool + resharded fragment
cache_s = jax.device_put(cache, jax.tree.map(
    lambda s: NamedSharding(mesh, s), cspecs))
with mesh:
    out = jax.jit(ServeEngine._insert_impl)(cache_s, frag_s, slot, row, keep)
ok_equal = all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)))

bt = np.asarray(jax.tree.leaves(
    out, is_leaf=lambda x: isinstance(x, PagedKVCache))[0].block_table)
ok_bind = list(bt[0, 1, :3]) == [2, 3, 4]

print(json.dumps({"ok_pool": bool(ok_pool), "ok_frag": bool(ok_frag),
                  "ok_reshard": bool(ok_reshard), "ok_equal": bool(ok_equal),
                  "ok_bind": bool(ok_bind), "k_spec": str(pool.k),
                  "frag_k_spec": str(kv.k)}))
"""


@pytest.mark.slow
def test_handoff_reshard_bitidentical_on_mesh():
    """Two-pool KV-page handoff under SPMD (DESIGN.md §10): on a 4x2
    (data, model) mesh the pool's page dim shards over `data` while the
    staged fragment keeps its token dim replicated (handoff_frag_specs —
    whole pages land on whichever shard owns them), `reshard_handoff` is
    a pure layout move, and the scatter+bind splice produces a pool
    bit-identical to the unsharded unified insert."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _HANDOFF_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    for key in ("ok_pool", "ok_frag", "ok_reshard", "ok_equal", "ok_bind"):
        assert res[key], res
