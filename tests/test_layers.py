"""Layer-level references: flash attention, RoPE, MoE router, Mamba2 SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-stub shim

from repro.core import PrecisionPolicy, use_policy
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.config import ArchConfig

FP32 = PrecisionPolicy(input_format="fp32")


def naive_attention(q, k, v, causal=True, window=0, cap=0.0):
    B, T, H, hd = q.shape
    S, KVH = k.shape[1], k.shape[2]
    g = H // KVH
    qg = q.reshape(B, T, KVH, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * hd ** -0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp, kp = jnp.arange(T), jnp.arange(S)
    ok = jnp.ones((T, S), bool)
    if causal:
        ok &= qp[:, None] >= kp[None, :]
    if window:
        ok &= qp[:, None] - kp[None, :] < window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, T, H, hd)


@pytest.mark.parametrize("kw", [
    dict(), dict(window=5), dict(cap=3.0), dict(causal=False),
    dict(cap=3.0, window=7)],
    ids=["causal", "window", "softcap", "bidir", "cap+win"])
def test_flash_vs_naive(kw):
    with use_policy(FP32):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (2, 16, 4, 8))
        k = jax.random.normal(ks[1], (2, 16, 2, 8))
        v = jax.random.normal(ks[2], (2, 16, 2, 8))
        out = L.blockwise_attention(q, k, v, block_q=4, block_kv=8, **kw)
        want = naive_attention(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        # gradients through the custom VJP
        g1 = jax.grad(lambda q: jnp.sum(jnp.sin(
            L.blockwise_attention(q, k, v, block_q=4, block_kv=8, **kw))))(q)
        g2 = jax.grad(lambda q: jnp.sum(jnp.sin(
            naive_attention(q, k, v, **kw))))(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=3e-5, atol=3e-5)


def test_flash_kv_grads():
    with use_policy(FP32):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 8, 2, 4))
        k = jax.random.normal(ks[1], (1, 8, 2, 4))
        v = jax.random.normal(ks[2], (1, 8, 2, 4))
        for argnum in (1, 2):
            g1 = jax.grad(lambda *a: jnp.sum(jnp.cos(L.blockwise_attention(
                *a, block_q=4, block_kv=4))), argnums=argnum)(q, k, v)
            g2 = jax.grad(lambda *a: jnp.sum(jnp.cos(
                naive_attention(*a))), argnums=argnum)(q, k, v)
            np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                       rtol=3e-5, atol=3e-5)


def test_rope_rotation_properties():
    x = jax.random.normal(jax.random.key(0), (1, 1, 6, 8))
    pos0 = jnp.zeros((1, 1), jnp.int32)
    # position 0 is the identity
    np.testing.assert_allclose(
        np.asarray(L.apply_rope(x.transpose(0, 2, 1, 3), pos0[:, None],
                                10000.0).transpose(0, 2, 1, 3)),
        np.asarray(x), rtol=1e-6)
    # norms preserved (rotation)
    posn = jnp.full((1, 1), 77, jnp.int32)
    y = L.apply_rope(x.transpose(0, 2, 1, 3), posn[:, None], 10000.0)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-5)
    # relative property: <rope(q,m), rope(k,n)> depends only on m−n
    q = jax.random.normal(jax.random.key(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.key(2), (1, 1, 1, 8))
    def dot_at(m, n):
        qm = L.apply_rope(q, jnp.full((1, 1, 1), m), 10000.0)
        kn = L.apply_rope(k, jnp.full((1, 1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))
    assert dot_at(5, 3) == pytest.approx(dot_at(105, 103), rel=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_router_properties(seed, k):
    E = 8
    x = jax.random.normal(jax.random.key(seed), (2, 6, 16))
    w = jax.random.normal(jax.random.key(seed + 1), (16, E)) * 0.1
    combine, aux = MOE.router(x, w, k)
    c = np.asarray(combine)
    # top-k weights renormalize to 1 per token; exactly k nonzero
    np.testing.assert_allclose(c.sum(-1), 1.0, rtol=1e-5)
    assert ((c > 0).sum(-1) == k).all()
    assert float(aux["load_balance"]) > 0.9   # ≈1 near-uniform, grows with skew
    assert np.isfinite(float(aux["router_z"]))


def test_moe_ffn_matches_dense_single_expert():
    """E=1, top-1: MoE must equal the plain SwiGLU FFN exactly."""
    with use_policy(FP32):
        cfg = ArchConfig(name="t", family="moe", num_layers=1, d_model=16,
                         num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=64,
                         num_experts=1, experts_per_token=1)
        ks = jax.random.split(jax.random.key(0), 4)
        x = jax.random.normal(ks[0], (2, 8, 16))
        p = {"router": jnp.zeros((16, 1)),
             "wg": jax.random.normal(ks[1], (1, 16, 32)) * 0.1,
             "wu": jax.random.normal(ks[2], (1, 16, 32)) * 0.1,
             "wd": jax.random.normal(ks[3], (1, 32, 16)) * 0.1}
        y, _ = MOE.moe_ffn(x, p, cfg, capacity_factor=1.0)
        want = L.ffn_swiglu(x, {"wg": p["wg"][0], "wu": p["wu"][0],
                                "wd": p["wd"][0]})
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_ssd_chunked_vs_naive_recurrence():
    """SSD chunked scan ≡ the token-by-token linear recurrence."""
    with use_policy(FP32):
        B, T, H, P, N = 2, 16, 3, 4, 5
        ks = jax.random.split(jax.random.key(0), 5)
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        B_ = jax.random.normal(ks[3], (B, T, N))
        C_ = jax.random.normal(ks[4], (B, T, N))
        y, S_fin = SSM.ssd_chunked(x, dt, A, B_, C_, chunk=4)
        # reference: S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_tᵀ; y_t = C_t·S_t
        S = np.zeros((B, H, P, N))
        ys = []
        for t in range(T):
            dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A))  # (B, H)
            dx = (np.asarray(dt[:, t])[..., None]
                  * np.asarray(x[:, t]))[..., None]
            S = (S * dA[..., None, None]
                 + dx * np.asarray(B_[:, t])[:, None, None, :])
            ys.append(np.einsum("bn,bhpn->bhp", np.asarray(C_[:, t]), S))
        want = np.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y), want, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(S_fin), S, rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_chunked():
    with use_policy(FP32):
        B, T, H, P, N = 1, 8, 2, 4, 3
        ks = jax.random.split(jax.random.key(1), 5)
        x = jax.random.normal(ks[0], (B, T, H, P))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        B_ = jax.random.normal(ks[3], (B, T, N))
        C_ = jax.random.normal(ks[4], (B, T, N))
        y_full, _ = SSM.ssd_chunked(x, dt, A, B_, C_, chunk=4)
        S = jnp.zeros((B, H, P, N))
        for t in range(T):
            S, y_t = SSM.ssd_decode_step(S, x[:, t], dt[:, t], A,
                                         B_[:, t], C_[:, t])
            np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, t]),
                                       rtol=2e-4, atol=2e-4)


def test_softcap_and_norms():
    x = jnp.asarray([[1.0, -2.0, 3.0]])
    assert float(L.softcap(x, 0.0)[0, 0]) == 1.0          # cap=0 disables
    assert abs(abs(float(L.softcap(x * 100, 30.0)[0, 2])) - 30.0) < 0.5
    w = jnp.ones((3,))
    y = L.rmsnorm(x, w)
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2))), 1.0, rtol=1e-4)
