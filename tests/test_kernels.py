"""Pallas kernels vs pure-jnp/numpy oracles (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fpformats import BF16, quantize_np
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _bf16_pair(m, k, n, scale=1.0):
    a = quantize_np(RNG.standard_normal((m, k)).astype(np.float32) * scale, BF16)
    w = quantize_np(RNG.standard_normal((k, n)).astype(np.float32) * scale, BF16)
    return a, w


# ---------------------------------------------------------------------------
# sa_matmul: shape / dtype / block sweeps vs the round-once oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (32, 64, 16), (128, 128, 128), (100, 96, 50),  # non-divisible
    (1, 256, 1), (256, 1, 256), (33, 257, 65),
])
def test_sa_matmul_shapes(m, k, n):
    a, w = _bf16_pair(m, k, n)
    y = ops.sa_matmul(jnp.asarray(a, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16),
                      bm=32, bn=32, bk=64)
    y_ref = ref.sa_matmul_ref(jnp.asarray(a, jnp.bfloat16),
                              jnp.asarray(w, jnp.bfloat16))
    scale = float(jnp.max(jnp.abs(y_ref))) + 1e-6
    assert float(jnp.max(jnp.abs(y - y_ref))) / scale < 2e-6
    assert y.shape == (m, n) and y.dtype == jnp.float32


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (64, 64, 32)])
def test_sa_matmul_block_sweep(bm, bn, bk):
    a, w = _bf16_pair(64, 96, 48)
    y = ops.sa_matmul(jnp.asarray(a, jnp.bfloat16), jnp.asarray(w, jnp.bfloat16),
                      bm=bm, bn=bn, bk=bk)
    y_ref = ref.sa_matmul_ref(jnp.asarray(a, jnp.bfloat16),
                              jnp.asarray(w, jnp.bfloat16))
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4


def test_sa_matmul_f32_inputs_exact():
    """fp32 path: single K block ⇒ bit-identical to jnp reference."""
    a = RNG.standard_normal((32, 48)).astype(np.float32)
    w = RNG.standard_normal((48, 16)).astype(np.float32)
    y = ops.sa_matmul(jnp.asarray(a), jnp.asarray(w), bm=32, bn=16, bk=48)
    y_ref = jnp.matmul(jnp.asarray(a), jnp.asarray(w),
                       preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


# ---------------------------------------------------------------------------
# fp_emu: the paper's exact datapath as a kernel, vs the numpy model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_name,scale", [
    ("bf16", 1.0), ("bf16", 25.0), ("fp8_e4m3", 1.0), ("fp8_e5m2", 1.0),
])
def test_fp_emu_bitexact(fmt_name, scale):
    from repro.core.fpformats import get_format
    fmt = get_format(fmt_name)
    a = quantize_np(RNG.standard_normal((24, 40)).astype(np.float32) * scale, fmt)
    w = quantize_np(RNG.standard_normal((40, 18)).astype(np.float32) * scale, fmt)
    y = np.asarray(ops.skewed_datapath_matmul(jnp.asarray(a), jnp.asarray(w),
                                              fmt_name))
    y_ref = ref.chained_fma_ref(a, w, fmt_name, "skewed")
    np.testing.assert_array_equal(y.view(np.uint32), y_ref.view(np.uint32))


def test_fp_emu_matches_mxu_contract():
    """For benign inputs (no cancellation-heavy truncation), the bit-exact
    skewed datapath agrees with the XLA bf16→f32 dot to fp32 roundoff."""
    a, w = _bf16_pair(16, 32, 16, scale=0.5)
    y_emu = np.asarray(ops.skewed_datapath_matmul(jnp.asarray(a), jnp.asarray(w)))
    y_mxu = np.asarray(jnp.matmul(jnp.asarray(a), jnp.asarray(w),
                                  preferred_element_type=jnp.float32))
    np.testing.assert_allclose(y_emu, y_mxu, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# quantize kernel vs fpformats oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2"])
def test_quantize_kernel(fmt):
    x = RNG.standard_normal((73, 19)).astype(np.float32) * 300
    scale = ops.amax_scale(jnp.asarray(x), fmt)
    y = ops.quantize_fp8(jnp.asarray(x), scale, fmt, interpret=True)
    y_ref = ref.quantize_ref(jnp.asarray(x), fmt, scale)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_fp8_gemm_end_to_end():
    a, w = _bf16_pair(64, 64, 64)
    y8 = ops.sa_matmul_fp8(jnp.asarray(a), jnp.asarray(w))
    y_ref = jnp.matmul(jnp.asarray(a), jnp.asarray(w),
                       preferred_element_type=jnp.float32)
    rel = float(jnp.linalg.norm(y8 - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 0.06     # e4m3: 3 mantissa bits ⇒ few-percent GEMM error
