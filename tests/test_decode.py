"""Serving correctness: prefill + decode ≡ full forward (fp32 exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import PrecisionPolicy, use_policy
from repro.configs import reduced_config
from repro.models import model as M
from repro.serve.engine import ServeEngine

FP32 = PrecisionPolicy(input_format="fp32")

DECODE_ARCHS = ["qwen2.5-14b", "gemma2-9b", "mamba2-2.7b", "hymba-1.5b",
                "granite-moe-3b-a800m", "whisper-tiny"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    import dataclasses
    cfg = reduced_config(arch)
    if cfg.remat:
        cfg = dataclasses.replace(cfg, remat=False)
    if cfg.num_experts:
        # the serving path (prefill+decode under a cache) always uses the
        # dropless dispatch — exact top-k routing. The full-forward
        # reference must use the same semantics: capacity-drop is a
        # training-time approximation that drops overflow tokens at T=12
        # (C=4) but structurally cannot drop at T=1, so it was never
        # decode-exact (the old xfail).
        cfg = dataclasses.replace(cfg, moe_dropless=True)
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
        B, T = 2, 12
        toks = jax.random.randint(jax.random.key(1), (B, T), 0,
                                  cfg.vocab_size)
        fe = None
        if cfg.is_encdec:
            fe = jax.random.normal(jax.random.key(2),
                                   (B, cfg.frontend_tokens, cfg.d_model))
        full, _, _ = M.forward(params, cfg, toks, frontend_embeds=fe)
        cache = M.init_cache(cfg, B, 16, dtype=jnp.float32)
        _, cache, _ = M.forward(params, cfg, toks[:, :T - 2], cache=cache,
                                frontend_embeds=fe)
        for t in range(T - 2, T):
            step, cache, _ = M.forward(params, cfg, toks[:, t:t + 1],
                                       cache=cache, pos=jnp.int32(t),
                                       frontend_embeds=fe)
            np.testing.assert_allclose(
                np.asarray(step[:, 0, :cfg.vocab_size]),
                np.asarray(full[:, t, :cfg.vocab_size]),
                rtol=1e-4, atol=1e-4)


def test_ring_buffer_window_decode():
    """Local-attention ring cache must equal full forward past the wrap."""
    import dataclasses
    cfg = dataclasses.replace(reduced_config("gemma3-12b"), remat=False)
    assert any(p == "local" for p in cfg.attn_pattern) and cfg.window == 8
    with use_policy(FP32):
        params = M.init_params(jax.random.key(0), cfg)
        B, T = 1, 20                       # > 2× window: cache wraps
        toks = jax.random.randint(jax.random.key(1), (B, T), 0,
                                  cfg.vocab_size)
        full, _, _ = M.forward(params, cfg, toks)
        cache = M.init_cache(cfg, B, T, dtype=jnp.float32)
        _, cache, _ = M.forward(params, cfg, toks[:, :4], cache=cache)
        for t in range(4, T):
            step, cache, _ = M.forward(params, cfg, toks[:, t:t + 1],
                                       cache=cache, pos=jnp.int32(t))
            np.testing.assert_allclose(
                np.asarray(step[:, 0, :cfg.vocab_size]),
                np.asarray(full[:, t, :cfg.vocab_size]),
                rtol=2e-4, atol=2e-4)


def test_serve_engine_generates():
    cfg = reduced_config("qwen2.5-14b")
    params = M.init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, batch=2, cache_len=24, eos_id=-1)
    prompts = jax.random.randint(jax.random.key(1), (2, 8), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())
    # greedy decoding is deterministic
    out2 = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
