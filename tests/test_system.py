"""End-to-end behaviour: training reduces loss; preemption checkpoint+resume
reproduces uninterrupted training; the precision policy plumbs end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.core import PrecisionPolicy, use_policy
from repro.data.pipeline import SyntheticLM
from repro.train import checkpoint as CKPT
from repro.train.optimizer import AdamW, constant_lr
from repro.train.step import make_train_step
from repro.train.train_state import init_state


def _run(cfg, steps, state, data, step_fn):
    losses = []
    jstep = jax.jit(step_fn)
    for i in range(int(state.step), steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = jstep(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def test_training_reduces_loss():
    cfg = reduced_config("qwen2.5-14b")
    opt = AdamW(schedule=constant_lr(3e-3), weight_decay=0.0)
    step_fn = make_train_step(cfg, opt)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    state = init_state(jax.random.key(0), cfg, opt)
    _, losses = _run(cfg, 25, state, data, step_fn)
    # synthetic uniform tokens: loss should drop toward log(V) from above
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    assert all(np.isfinite(losses))


def test_preempt_checkpoint_resume_bitexact(tmp_path):
    """Train 6 steps straight vs 3 + checkpoint + restore + 3: identical."""
    cfg = reduced_config("granite-moe-3b-a800m")
    opt = AdamW(schedule=constant_lr(1e-3))
    step_fn = make_train_step(cfg, opt)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=1)

    state_a = init_state(jax.random.key(0), cfg, opt)
    state_a, losses_a = _run(cfg, 6, state_a, data, step_fn)

    state_b = init_state(jax.random.key(0), cfg, opt)
    state_b, _ = _run(cfg, 3, state_b, data, step_fn)
    CKPT.save(str(tmp_path), 3, state_b)
    restored, _, start = CKPT.restore(str(tmp_path), state_b)
    assert start == 3
    state_b, losses_b = _run(cfg, 6, restored, data, step_fn)

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_precision_policy_changes_arithmetic():
    """fp8 vs bf16 vs fp32 policies give measurably different logits —
    the paper's datapath is live in the full model, not a no-op flag."""
    cfg = reduced_config("phi3-medium-14b")
    from repro.models import model as M
    params = M.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)

    outs = {}
    for fmt in ("fp32", "bf16", "fp8_e4m3"):
        with use_policy(PrecisionPolicy(input_format=fmt)):
            logits, _, _ = M.forward(params, cfg, toks)
            outs[fmt] = np.asarray(logits[..., :cfg.vocab_size])
    d_bf = np.abs(outs["bf16"] - outs["fp32"]).max()
    d_f8 = np.abs(outs["fp8_e4m3"] - outs["fp32"]).max()
    assert 0 < d_bf < d_f8          # precision ladder orders correctly
    # all close in distribution: top-1 token mostly agrees bf16 vs fp32
    agree = (outs["bf16"].argmax(-1) == outs["fp32"].argmax(-1)).mean()
    assert agree > 0.8


def test_emulate_backend_matches_xla_exactly_small():
    """The bit-exact SA emulation == XLA bf16 dot on a real GEMM."""
    from repro.core import sa_dot
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((16, 32)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((32, 8)).astype(np.float32))
    with use_policy(PrecisionPolicy(backend="emulate")):
        y_emu = sa_dot(a, w)
    y_xla = sa_dot(a, w)
    np.testing.assert_allclose(np.asarray(y_emu), np.asarray(y_xla),
                               rtol=2e-7, atol=2e-7)
